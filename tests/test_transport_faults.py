"""Fault injection for the remote serving tier.

Every failure mode the wire introduces is injected deterministically
here and must resolve ONLY the affected futures with a *typed* error —
co-tenants complete (or are cleanly retried), the daemon never wedges,
and nothing hangs past its deadline.  This mirrors the in-process
poison-request discipline of ``tests/test_serve.py``: one tenant's
misfortune is never a co-tenant's problem.

Faults covered: connection drop mid-request (client-daemon proxy cut),
truncated frames in both directions, worker SIGKILL mid-dispatch (with
requeue-or-fail retry through the respawned worker), deadline expiry,
and admission-control overload.

The daemon fixture is module-scoped (a worker spawn pays the jax
import); fault tests that mutate it (the SIGKILL test) self-heal
through the daemon's supervision before the next test runs.
"""

from __future__ import annotations

import os
import signal
import socket
import struct
import threading
import time
from types import SimpleNamespace

import numpy as np
import pytest

from repro.serve import SimClient
from repro.serve import router
from repro.serve import transport as tp
from repro.serve.daemon import ServeDaemon

pytestmark = pytest.mark.filterwarnings("ignore::ResourceWarning")


# ---------------------------------------------------------------------------
# transport-level faults: proxy shim, no jax anywhere
# ---------------------------------------------------------------------------

class FaultyProxy:
    """A TCP shim between a client and an RPC server that can cut the
    link mid-request or truncate a frame in flight."""

    def __init__(self, upstream):
        self.upstream = tp.parse_addr(upstream)
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind(("127.0.0.1", 0))
        self._listener.listen(8)
        self.addr = self._listener.getsockname()[:2]
        self._pairs: list = []
        self._lock = threading.Lock()
        # None = forward freely; an int = forward that many more
        # upstream->client bytes, then cut both sides
        self._budget = None
        threading.Thread(target=self._accept, daemon=True).start()

    def _accept(self):
        while True:
            try:
                client, _ = self._listener.accept()
            except OSError:
                return
            up = socket.create_connection(self.upstream, timeout=5.0)
            with self._lock:
                self._pairs.append((client, up))
            threading.Thread(target=self._pump, args=(client, up, False),
                             daemon=True).start()
            threading.Thread(target=self._pump, args=(up, client, True),
                             daemon=True).start()

    def _pump(self, src, dst, downstream):
        while True:
            try:
                data = src.recv(65536)
            except OSError:
                return
            if not data:
                for s in (src, dst):
                    try:
                        s.shutdown(socket.SHUT_RDWR)
                    except OSError:
                        pass
                    try:
                        s.close()
                    except OSError:
                        pass
                return
            if downstream:
                with self._lock:
                    if self._budget is not None:
                        data = data[:self._budget]
                        self._budget -= len(data)
                        cut = self._budget <= 0
                    else:
                        cut = False
                if data:
                    try:
                        dst.sendall(data)
                    except OSError:
                        return
                if cut:
                    self.drop()
                    return
            else:
                try:
                    dst.sendall(data)
                except OSError:
                    return

    def truncate_downstream_after(self, nbytes: int) -> None:
        with self._lock:
            self._budget = nbytes

    def drop(self) -> None:
        with self._lock:
            pairs, self._pairs = self._pairs, []
        for a, b in pairs:
            for s in (a, b):
                try:
                    s.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                                 struct.pack("ii", 1, 0))
                except OSError:
                    pass
                try:
                    # shutdown wakes any thread blocked in recv; close
                    # alone would leave it parked on the dead fd
                    s.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass
                try:
                    s.close()
                except OSError:
                    pass

    def close(self) -> None:
        self.drop()
        try:
            self._listener.close()
        except OSError:
            pass


def _slow_server():
    """An RpcServer whose 'slow' method defers its reply ~0.4s."""
    def slow(params, ctx):
        out = tp.RpcFuture()
        t = threading.Timer(0.4, out.set_result, args=({"ok": 1},))
        t.daemon = True
        t.start()
        return out
    return tp.RpcServer({"echo": lambda p, c: p, "slow": slow}).start()


def test_connection_drop_mid_request_fails_only_that_client():
    srv = _slow_server()
    proxy = FaultyProxy(srv.addr)
    victim = tp.RpcClient(proxy.addr)
    bystander = tp.RpcClient(srv.addr)      # direct, different connection
    try:
        pending = victim.call_async("slow", {})
        busy = bystander.call_async("slow", {})
        time.sleep(0.05)                    # request is in flight
        proxy.drop()
        with pytest.raises(tp.ConnectionLost):
            pending.result(timeout=5.0)
        # the co-tenant connection is untouched and completes
        assert busy.result(timeout=5.0) == {"ok": 1}
        assert bystander.call("echo", {"x": 2}, deadline_s=5.0)["x"] == 2
    finally:
        victim.close()
        bystander.close()
        proxy.close()
        srv.stop()


def test_truncated_response_frame_fails_pending_typed():
    srv = _slow_server()
    proxy = FaultyProxy(srv.addr)
    client = tp.RpcClient(proxy.addr)
    try:
        # let the handshake-free transport settle one echo first so the
        # truncation hits the *response* of the slow call
        assert client.call("echo", {"v": 1}, deadline_s=5.0)["v"] == 1
        proxy.truncate_downstream_after(5)  # a few header bytes, then cut
        fut = client.call_async("slow", {})
        with pytest.raises(tp.ConnectionLost):
            fut.result(timeout=5.0)
        assert not client.alive             # poisoned handle, typed dead
    finally:
        client.close()
        proxy.close()
        srv.stop()


def test_truncated_request_frame_closes_only_that_connection():
    srv = tp.RpcServer({"echo": lambda p, c: p}).start()
    try:
        # a raw peer sends half a frame and vanishes
        raw = socket.create_connection(srv.addr)
        frame = tp.pack_frame({"id": 1, "method": "echo", "params": {}})
        raw.sendall(frame[: len(frame) // 2])
        raw.close()
        # the server shed that connection; fresh clients are unaffected
        client = tp.RpcClient(srv.addr)
        assert client.call("echo", {"x": 3}, deadline_s=5.0)["x"] == 3
        client.close()
    finally:
        srv.stop()


def test_garbage_bytes_do_not_wedge_server():
    srv = tp.RpcServer({"echo": lambda p, c: p}).start()
    try:
        raw = socket.create_connection(srv.addr)
        raw.sendall(b"GET / HTTP/1.1\r\n\r\n" * 10)
        raw.close()
        client = tp.RpcClient(srv.addr)
        assert client.call("echo", {"x": 4}, deadline_s=5.0)["x"] == 4
        client.close()
    finally:
        srv.stop()


def test_deadline_on_silent_peer_is_typed_and_on_time():
    srv = tp.RpcServer({"never": lambda p, c: tp.RpcFuture()}).start()
    client = tp.RpcClient(srv.addr)
    try:
        t0 = time.monotonic()
        with pytest.raises(tp.DeadlineExceeded):
            client.call("never", {}, deadline_s=0.3)
        assert time.monotonic() - t0 < 2.0
    finally:
        client.close()
        srv.stop()


# ---------------------------------------------------------------------------
# full-stack faults: daemon + real worker subprocess
# ---------------------------------------------------------------------------

K, N_STREAM, T = 8, 400, 40


@pytest.fixture(scope="module")
def stream_arrays():
    rng = np.random.default_rng(7)
    return (rng.normal(0, 1, (K, N_STREAM)).astype(np.float32),
            rng.normal(0, 1, N_STREAM).astype(np.float32),
            rng.uniform(0.5, 2.0, K).astype(np.float32))


@pytest.fixture(scope="module")
def daemon(stream_arrays):
    d = ServeDaemon(max_pending=32, retry_limit=2, heartbeat_s=0.3,
                    heartbeat_misses=2,
                    worker_args={"max_batch": 8, "max_wait_ms": 1.0})
    d.start()
    client = SimClient.connect(d.addr, retries=0)
    client.server.register_stream("default", *stream_arrays)
    # warm the worker's executable cache so fault tests measure fault
    # handling, not compile time
    client.map([dict(algo="eflfg", seed=s, T=T) for s in range(2)],
               timeout=180.0)
    client.close()
    yield d
    d.drain_and_stop()


def test_worker_sigkill_mid_dispatch_retries_or_fails_typed(daemon):
    client = SimClient.connect(daemon.addr, retries=0)
    try:
        # a fresh T forces a compile on the worker: requests stay
        # in-flight long enough to be killed mid-dispatch
        futs = [client.submit("eflfg", s, T=T + 7) for s in range(6)]
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            st = daemon.status()
            if st["inflight"] > 0 and st["worker"]["pid"]:
                break
            time.sleep(0.01)
        pid = daemon.status()["worker"]["pid"]
        assert pid, "no worker to kill"
        os.kill(pid, signal.SIGKILL)
        # the fleet metrics merge must not wedge on the dead worker: the
        # SIGKILLed peer simply drops out (workers_reporting says so)
        doc = daemon.metrics_doc(per_worker_deadline_s=1.0)
        assert doc["workers_total"] == 1
        assert "daemon.admitted" in doc["merged"]["counters"]
        # every future settles: retried onto the respawned worker (the
        # requeue-or-fail path) or failed typed — never hung
        outcomes = []
        for f in futs:
            try:
                outcomes.append(f.result(timeout=240.0))
            except tp.WorkerDied as exc:
                outcomes.append(exc)
        assert all(o is not None for o in outcomes)
        completed = [o for o in outcomes if not isinstance(o, Exception)]
        # the retry budget (2) covers one kill: everything completes
        assert len(completed) == len(futs), \
            [type(o).__name__ for o in outcomes]
        st = daemon.status()
        assert st["worker"]["restarts"] >= 1
        assert st["counters"]["retried"] >= 1
        # the retried requests' span timelines show EXACTLY one retry
        # each (one kill, one requeue) and stitch across processes: the
        # client/daemon spans carry this pid, the respawned worker's
        # dispatch spans its own
        from repro import obs
        retried_traces = []
        for f in futs:
            tctx = f.request.trace
            if tctx is None:
                continue                 # obs disabled in this env
            doc = daemon.trace_doc(tctx["trace_id"])
            retries = [s for s in doc["spans"]
                       if s["name"] == "daemon.retried"]
            if retries:
                retried_traces.append((doc, retries))
        if obs.enabled():
            assert retried_traces, "no retried trace recorded"
            for doc, retries in retried_traces:
                assert len(retries) == 1, \
                    [s["name"] for s in doc["spans"]]
                assert len({s["pid"] for s in doc["spans"]}) >= 2, \
                    "timeline did not stitch across processes"
        # the merge recovers cleanly after respawn, no double-count:
        # merged daemon counters equal the daemon's own section (worker
        # snapshots never carry daemon.* names), twice in a row
        for _ in range(2):
            doc = daemon.metrics_doc(per_worker_deadline_s=5.0)
            assert doc["merged"]["counters"]["daemon.admitted"] == \
                doc["daemon"]["counters"]["daemon.admitted"]
        assert doc["workers_reporting"] == 1
        assert doc["merged"]["counters"]["server.submitted"] >= 1
        # and the respawned worker serves new traffic
        res = client.run("fedboost", 11, T=T, timeout=240.0)
        assert res.mse_curve.shape == (T,)
    finally:
        client.close()


def test_deadline_expiry_drops_before_dispatch_typed(daemon):
    client = SimClient.connect(daemon.addr, retries=0)
    try:
        t0 = time.monotonic()
        fut = client.submit("eflfg", 123, T=T, deadline_s=0.001)
        with pytest.raises(tp.DeadlineExceeded):
            fut.result(timeout=10.0)
        # "within the deadline" means promptly after it, not eventually
        assert time.monotonic() - t0 < 5.0
        assert daemon.status()["counters"]["expired"] >= 0
    finally:
        client.close()


def test_overload_rejects_typed_and_co_tenants_complete(daemon):
    tight = ServeDaemon(max_pending=3, retry_limit=1, heartbeat_s=0.5,
                        worker_args={"max_batch": 4, "max_wait_ms": 1.0})
    tight.start()
    client = SimClient.connect(tight.addr, retries=0)
    try:
        client.server.register_stream(
            "default",
            *[np.asarray(a) for a in (np.random.default_rng(1).normal(
                0, 1, (K, N_STREAM)).astype(np.float32),
                np.zeros(N_STREAM, np.float32),
                np.ones(K, np.float32))])
        futs = [client.submit("eflfg", s, T=T + 13) for s in range(12)]
        rejected, served = 0, 0
        for f in futs:
            try:
                f.result(timeout=240.0)
                served += 1
            except tp.Overloaded:
                rejected += 1
        assert rejected >= 1, "admission control never engaged"
        assert served >= 1, "co-tenant admissions must still complete"
        assert served + rejected == len(futs)
        assert tight.status()["counters"]["rejected"] >= rejected
    finally:
        client.close()
        tight.drain_and_stop()


def test_overloaded_submits_retry_with_backoff_to_completion(daemon):
    tight = ServeDaemon(max_pending=2, retry_limit=1, heartbeat_s=0.5,
                        worker_args={"max_batch": 4, "max_wait_ms": 1.0})
    tight.start()
    client = SimClient.connect(tight.addr, retries=6, backoff_s=0.2)
    try:
        rng = np.random.default_rng(2)
        client.server.register_stream(
            "default", rng.normal(0, 1, (K, N_STREAM)).astype(np.float32),
            np.zeros(N_STREAM, np.float32), np.ones(K, np.float32))
        futs = [client.submit("fedboost", s, T=T + 21) for s in range(8)]
        results = [f.result(timeout=300.0) for f in futs]
        assert all(r.mse_curve.shape == (T + 21,) for r in results)
        assert tight.status()["counters"]["rejected"] >= 1, \
            "load never tripped admission control (weak test setup)"
    finally:
        client.close()
        tight.drain_and_stop()


def test_daemon_serves_normally_after_all_faults(daemon):
    client = SimClient.connect(daemon.addr)
    try:
        results = client.map(
            [dict(algo="eflfg", seed=s, T=T) for s in range(4)],
            timeout=240.0)
        assert len(results) == 4
        st = daemon.status()
        assert st["queued"] == 0 and st["inflight"] == 0
        assert not st["draining"] and st["worker"]["alive"]
    finally:
        client.close()


# ---------------------------------------------------------------------------
# multi-worker chaos matrix: a 2-worker pool under injected failures.
# Affected futures resolve typed or with exactly one requeue; the
# co-worker's traffic is bit-unaffected; the daemon never wedges.
# ---------------------------------------------------------------------------

def _pick_streams():
    """Two stream names whose version-1 rendezvous homes differ, so each
    pool slot carries its own tenant."""
    names = (f"tenant{i}" for i in range(100))
    a = next(n for n in names if router.affine_worker(n, 1, [0, 1]) == 0)
    b = next(n for n in names if router.affine_worker(n, 1, [0, 1]) == 1)
    return a, b


def _mk_arrays(seed):
    rng = np.random.default_rng(seed)
    return (rng.normal(0, 1, (K, N_STREAM)).astype(np.float32),
            rng.normal(0, 1, N_STREAM).astype(np.float32),
            rng.uniform(0.5, 2.0, K).astype(np.float32))


def _wait_pool_alive(d, timeout=60.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if all(w["alive"] for w in d.status()["workers"]):
            return
        time.sleep(0.05)
    raise AssertionError(f"pool did not heal: {d.status()['workers']}")


@pytest.fixture(scope="module")
def pool(stream_arrays):
    stream_a, stream_b = _pick_streams()
    d = ServeDaemon(workers=2, max_pending=64, retry_limit=2,
                    heartbeat_s=0.3, heartbeat_misses=2,
                    worker_args={"max_batch": 8, "max_wait_ms": 1.0})
    d.start()
    client = SimClient.connect(d.addr, retries=0)
    client.server.register_stream(stream_a, *stream_arrays)
    client.server.register_stream(stream_b, *_mk_arrays(11))
    # warm both workers' executable caches through their own streams
    for s in (stream_a, stream_b):
        client.map([dict(algo="eflfg", seed=i, T=T, stream=s)
                    for i in range(2)], timeout=240.0)
    client.close()
    yield SimpleNamespace(d=d, a=stream_a, b=stream_b)
    d.drain_and_stop()


@pytest.mark.ordered_soak
def test_pool_routes_tenants_to_distinct_workers(pool):
    st = pool.d.status()
    assert [w["id"] for w in st["workers"]] == [0, 1]
    assert all(w["alive"] for w in st["workers"])
    assert pool.a in st["workers"][0]["streams"]
    assert pool.b in st["workers"][1]["streams"]
    client = SimClient.connect(pool.d.addr, retries=0)
    try:
        fa = client.submit("eflfg", 50, T=T, stream=pool.a)
        fb = client.submit("eflfg", 50, T=T, stream=pool.b)
        fa.result(timeout=240.0), fb.result(timeout=240.0)
    finally:
        client.close()


@pytest.mark.ordered_soak
def test_pool_sigkill_one_worker_mid_load_spares_the_other(pool):
    """SIGKILL the worker serving tenant A under two-tenant load: A's
    futures settle via requeue-or-fail (retry budget covers one kill),
    B's results are bit-equal to its pre-chaos reference, and only
    slot 0 restarts."""
    d = pool.d
    specs_b = [dict(algo="eflfg", seed=100 + s, T=T, stream=pool.b)
               for s in range(4)]
    client = SimClient.connect(d.addr, retries=0)
    try:
        reference = client.map(specs_b, timeout=240.0)      # pre-chaos
        restarts_before = d.status()["workers"][0]["restarts"]
        # fresh T on tenant A: a compile keeps its requests in flight
        futs_a = [client.submit("eflfg", s, T=T + 3, stream=pool.a)
                  for s in range(6)]
        futs_b = [client.submit(**spec) for spec in specs_b]
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            st = d.status()
            if st["workers"][0]["inflight"] > 0 and st["workers"][0]["pid"]:
                break
            time.sleep(0.01)
        pid = d.status()["workers"][0]["pid"]
        assert pid, "no worker 0 to kill"
        os.kill(pid, signal.SIGKILL)
        # every tenant-A future settles: retried onto the respawned (or
        # spilled-to) worker, or failed typed — never hung
        outcomes_a = []
        for f in futs_a:
            try:
                outcomes_a.append(f.result(timeout=240.0))
            except tp.WorkerDied as exc:
                outcomes_a.append(exc)
        assert len([o for o in outcomes_a
                    if not isinstance(o, Exception)]) == len(futs_a), \
            [type(o).__name__ for o in outcomes_a]
        # the co-worker's tenant is bit-unaffected by the chaos
        results_b = [f.result(timeout=240.0) for f in futs_b]
        for got, want in zip(results_b, reference):
            assert got.identical_to(want), got.identical_fields(want)
        st = d.status()
        assert st["workers"][0]["restarts"] > restarts_before
        assert st["workers"][1]["restarts"] == 0
        _wait_pool_alive(d)
    finally:
        client.close()


@pytest.mark.ordered_soak
def test_pool_kill_affine_worker_of_just_reregistered_stream(pool):
    """Re-register tenant A (version bump re-homes it), SIGKILL its new
    affine worker immediately: traffic re-routes to the survivor (which
    learns the stream lazily) or the respawn — results carry the NEW
    data, bit-equal to a direct scan."""
    from dataclasses import replace

    from repro.federated import SimConfig, run_simulation_scan

    d = pool.d
    preds, y, costs = _mk_arrays(23)
    client = SimClient.connect(d.addr, retries=0)
    try:
        client.server.register_stream(pool.a, preds, y, costs)
        version = d.status()["streams"][pool.a]
        home = router.affine_worker(pool.a, version, [0, 1])
        pid = d.status()["workers"][home]["pid"]
        assert pid, "no affine worker to kill"
        os.kill(pid, signal.SIGKILL)
        fut = client.submit("eflfg", 9, T=T, stream=pool.a, exact=True)
        res = fut.result(timeout=240.0)
        direct = run_simulation_scan("eflfg", preds, y, costs, T,
                                     replace(SimConfig(), seed=9))
        assert res.identical_to(direct), res.identical_fields(direct)
        _wait_pool_alive(d)
    finally:
        client.close()


def test_pool_kill_during_drain_survivor_absorbs_backlog(stream_arrays):
    """SIGKILL one worker while the daemon is draining: its restored
    claims re-route to the survivor (draining skips respawn), every
    admitted future completes or fails typed, and the drain finishes —
    the daemon never wedges."""
    stream_a, stream_b = _pick_streams()
    d = ServeDaemon(workers=2, max_pending=64, retry_limit=2,
                    heartbeat_s=0.3, heartbeat_misses=2,
                    worker_args={"max_batch": 8, "max_wait_ms": 1.0})
    d.start()
    client = SimClient.connect(d.addr, retries=0)
    try:
        client.server.register_stream(stream_a, *stream_arrays)
        client.server.register_stream(stream_b, *_mk_arrays(31))
        for s in (stream_a, stream_b):
            client.map([dict(algo="eflfg", seed=i, T=T, stream=s)
                        for i in range(2)], timeout=240.0)
        # fresh T: compiles keep requests in flight through the drain
        admitted_before = d.status()["counters"]["admitted"]
        futs = [client.submit("eflfg", s, T=T + 11, stream=st)
                for s in range(4) for st in (stream_a, stream_b)]
        deadline = time.monotonic() + 30.0
        while (time.monotonic() < deadline
               and d.status()["counters"]["admitted"]
               < admitted_before + len(futs)):
            time.sleep(0.005)           # drain only after full admission
        stopper = threading.Thread(target=d.drain_and_stop,
                                   kwargs={"timeout": 240.0}, daemon=True)
        stopper.start()
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline and not d._draining:
            time.sleep(0.005)
        pid = d.status()["workers"][0]["pid"]
        if pid:                         # may already be shut down
            os.kill(pid, signal.SIGKILL)
        outcomes = []
        for f in futs:
            try:
                outcomes.append(f.result(timeout=240.0))
            except (tp.WorkerDied, tp.ConnectionLost) as exc:
                outcomes.append(exc)
        assert len(outcomes) == len(futs)       # all settled: no hangs
        # the survivor absorbed at least tenant B's traffic
        completed = [o for o in outcomes if not isinstance(o, Exception)]
        assert completed, [type(o).__name__ for o in outcomes]
        stopper.join(timeout=300.0)
        assert not stopper.is_alive(), "drain wedged"
        assert d._stopped.is_set()
    finally:
        client.close()
        d.drain_and_stop()
