"""``repro.obs`` — metrics, tracing, and the observe-only contract.

Four layers, cheapest first:

* **metrics units** — counter/gauge/histogram semantics, thread safety,
  the merge algebra (counters and gauges sum, histograms add
  bucket-wise, NaN gauge reads are skipped, mismatched bounds raise),
  merged-quantile accuracy, and both render surfaces (deterministic
  JSON, Prometheus text exposition).
* **tracer units** — context minting and inheritance, the disabled
  no-op path, the bounded ring buffer, retroactive spans, and the
  Perfetto export shape.
* **queue instruments** — ``RequestQueue`` registers live depth/age
  gauges and a claim-time wait histogram; a seeded concurrency stress
  (producer vs racing drainers, mirroring the restore stress in
  ``test_served_daemon``) pins that instrument counts stay consistent
  under real interleavings.
* **in-process serve** — ``SimServer`` on the registry: ``stats()``
  keeps its legacy flat keys AND exposes the typed snapshot; a traced
  request's timeline reads submitted → queued → dispatch; and THE
  contract: a wave served with tracing enabled is bit-equal
  (``identical_to``) to the same wave with tracing disabled.  (The
  sustained-load version of that pin is the ``serve.obs_overhead``
  BENCH cell; this is the fast deterministic twin.)
"""

from __future__ import annotations

import json
import math
import random
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.serve.queue import RequestQueue, SimFuture, SimRequest


@pytest.fixture(autouse=True)
def _obs_enabled():
    """Force a known switch state per test and isolate the ring."""
    prev = obs.set_enabled(True)
    obs.TRACER.clear()
    yield
    obs.set_enabled(prev)
    obs.TRACER.clear()


def _req(seed: int = 0, **kw) -> SimRequest:
    return SimRequest(algo="eflfg", seed=seed, T=8, **kw)


# ---------------------------------------------------------------------------
# metrics units
# ---------------------------------------------------------------------------

def test_counter_inc_is_atomic_under_threads():
    reg = obs.MetricsRegistry()
    c = reg.counter("t.hits")
    seen = []

    def worker():
        for _ in range(1000):
            seen.append(c.inc())

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 8000
    # inc() returns the post-increment value: usable as a sequence
    assert sorted(seen) == list(range(1, 8001))


def test_gauge_set_fn_evaluated_at_snapshot_and_nan_on_error():
    reg = obs.MetricsRegistry()
    g = reg.gauge("t.depth")
    backing = [3]
    g.set_fn(lambda: backing[0])
    assert reg.snapshot()["gauges"]["t.depth"] == 3
    backing[0] = 7
    assert reg.snapshot()["gauges"]["t.depth"] == 7      # live, not cached
    g.set_fn(lambda: 1 / 0)
    assert math.isnan(reg.snapshot()["gauges"]["t.depth"])
    g.set(2.5)                                           # explicit wins
    assert reg.snapshot()["gauges"]["t.depth"] == 2.5


def test_registry_type_conflict_raises_and_get_or_create_is_stable():
    reg = obs.MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x")


def test_histogram_merge_and_fleet_quantiles():
    """The load-bearing property: percentiles of MERGED per-worker
    snapshots track the pooled sample distribution without any process
    storing samples."""
    rng = random.Random(7)
    samples = [rng.lognormvariate(-3.0, 1.5) for _ in range(4000)]
    regs = [obs.MetricsRegistry() for _ in range(4)]
    for i, v in enumerate(samples):
        regs[i % 4].histogram("t.wait_s").observe(v)
    merged = obs.MetricsRegistry.merge([r.snapshot() for r in regs])
    h = merged["histograms"]["t.wait_s"]
    assert h["count"] == len(samples)
    assert h["sum"] == pytest.approx(sum(samples))
    assert h["min"] == pytest.approx(min(samples))
    assert h["max"] == pytest.approx(max(samples))
    ordered = sorted(samples)
    for q in (0.5, 0.9, 0.99):
        est = obs.quantile(h, q)
        exact = ordered[int(q * (len(ordered) - 1))]
        # log-spaced buckets (3/decade): estimates land within a bucket
        # width — a factor ~2.2 — of the exact sample quantile
        assert exact / 2.3 <= est <= exact * 2.3, (q, est, exact)
        assert h["min"] <= est <= h["max"]          # clamped to observed


def test_merge_sums_counters_and_gauges_and_skips_nan():
    a = {"counters": {"n": 2}, "gauges": {"d": 1.0}, "histograms": {}}
    b = {"counters": {"n": 3, "m": 1}, "gauges": {"d": float("nan")},
         "histograms": {}}
    merged = obs.MetricsRegistry.merge([a, b])
    assert merged["counters"] == {"n": 5, "m": 1}
    assert merged["gauges"] == {"d": 1.0}           # NaN read skipped


def test_merge_rejects_mismatched_bounds():
    r1, r2 = obs.MetricsRegistry(), obs.MetricsRegistry()
    r1.histogram("h").observe(0.1)
    r2.histogram("h", bounds=(1.0, 2.0)).observe(1.5)
    with pytest.raises(ValueError, match="bounds mismatch"):
        obs.MetricsRegistry.merge([r1.snapshot(), r2.snapshot()])


def test_log_bounds_cover_the_documented_range():
    b = obs.log_bounds()
    assert b[0] == pytest.approx(1e-4) and b[-1] == pytest.approx(1e3)
    assert list(b) == sorted(b) and len(b) == 22
    with pytest.raises(ValueError):
        obs.log_bounds(lo=-1.0)


def test_render_surfaces_json_and_prometheus():
    reg = obs.MetricsRegistry()
    reg.counter("daemon.admitted").inc(4)
    reg.gauge("daemon.queue.depth").set(2)
    reg.histogram("daemon.queue.wait_s").observe(0.25)
    snap = reg.snapshot()
    assert json.loads(obs.to_json(snap)) == json.loads(obs.to_json(snap))
    text = obs.render_prometheus(snap)
    assert "# TYPE repro_daemon_admitted_total counter" in text
    assert "repro_daemon_admitted_total 4" in text
    assert "repro_daemon_queue_depth 2" in text
    # cumulative le-buckets with the +Inf terminator, sum and count
    assert 'repro_daemon_queue_wait_s_bucket{le="+Inf"} 1' in text
    assert "repro_daemon_queue_wait_s_count 1" in text
    assert text.endswith("\n")


# ---------------------------------------------------------------------------
# tracer units
# ---------------------------------------------------------------------------

def test_mint_child_inherits_trace_id_with_fresh_span_id():
    root = obs.mint()
    assert set(root) == {"trace_id", "span_id"}
    assert len(root["trace_id"]) == 16 and len(root["span_id"]) == 8
    kid = obs.child(root)
    assert kid["trace_id"] == root["trace_id"]
    assert kid["span_id"] != root["span_id"]
    assert obs.child(None) is None


def test_disabled_mint_and_record_are_noops():
    tr = obs.Tracer("test")
    with obs.scoped(False):
        assert obs.mint() is None
        tr.record("x", {"trace_id": "aa", "span_id": "bb"})
    assert tr.spans() == []
    tr.record("x", None)                    # untraced request: no-op
    assert tr.spans() == []


def test_ring_buffer_is_bounded_and_oldest_falls_off():
    tr = obs.Tracer("test", capacity=10)
    ctx = obs.mint()
    for i in range(25):
        tr.event(f"e{i}", ctx)
    names = [s["name"] for s in tr.spans()]
    assert names == [f"e{i}" for i in range(15, 25)]


def test_retroactive_span_and_wall_clock_anchor():
    tr = obs.Tracer("test")
    ctx = obs.mint()
    t0 = time.monotonic() - 0.5
    tr.record("queued", ctx, t0=t0, attrs={"stream": "default"})
    (s,) = tr.spans(ctx["trace_id"])
    assert s["dur_s"] == pytest.approx(0.5, abs=0.05)
    assert s["t0_wall"] == pytest.approx(obs.clock.to_wall(t0))
    assert s["attrs"] == {"stream": "default"}
    assert s["parent_id"] == ctx["span_id"]


def test_traces_lists_distinct_ids_newest_first():
    tr = obs.Tracer("test")
    a, b = obs.mint(), obs.mint()
    tr.event("first", a)
    tr.event("second", b)
    tr.event("third", a)
    recent = tr.traces()
    assert [r["trace_id"] for r in recent] == [b["trace_id"],
                                               a["trace_id"]]
    assert recent[1]["n_spans"] == 2
    assert recent[1]["names"] == ["first", "third"]


def test_perfetto_export_shape():
    tr = obs.Tracer("daemon")
    ctx = obs.mint()
    tr.record("dispatch", ctx, t0=time.monotonic() - 0.01,
              attrs={"worker": 1})
    doc = obs.to_perfetto(tr.spans())
    events = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    metas = [e for e in doc["traceEvents"] if e.get("ph") == "M"]
    assert len(events) == 1 and len(metas) == 1
    (e,) = events
    assert e["name"] == "dispatch" and e["cat"] == "daemon"
    assert e["dur"] >= 1.0 and e["args"]["worker"] == 1
    assert metas[0]["args"]["name"] == "daemon"
    json.dumps(doc)                         # chrome://tracing-loadable


def test_wire_trace_field_is_sanitized():
    from repro.serve.wire import valid_trace
    assert valid_trace({"trace_id": "ab", "span_id": "cd"}) == \
        {"trace_id": "ab", "span_id": "cd"}
    assert valid_trace(None) is None
    assert valid_trace("junk") is None
    assert valid_trace({"trace_id": "", "span_id": "x"}) is None
    assert valid_trace({"trace_id": "a" * 65, "span_id": "x"}) is None
    assert valid_trace({"trace_id": 7, "span_id": "x"}) is None


# ---------------------------------------------------------------------------
# queue instruments
# ---------------------------------------------------------------------------

def test_queue_registers_depth_age_and_wait_instruments():
    reg = obs.MetricsRegistry()
    q = RequestQueue(registry=reg, prefix="daemon")
    r0, r1 = _req(0), _req(1)
    q.put(r0, SimFuture(r0))
    time.sleep(0.02)
    q.put(r1, SimFuture(r1))
    snap = reg.snapshot()
    assert snap["gauges"]["daemon.queue.depth"] == 2
    assert snap["gauges"]["daemon.queue.oldest_age_s"] >= 0.02
    assert snap["histograms"]["daemon.queue.wait_s"]["count"] == 0
    q.drain(max_n=8, wait_s=0.0)
    snap = reg.snapshot()
    assert snap["gauges"]["daemon.queue.depth"] == 0
    assert snap["gauges"]["daemon.queue.oldest_age_s"] == 0.0
    h = snap["histograms"]["daemon.queue.wait_s"]
    assert h["count"] == 2 and h["max"] >= 0.02


def test_queue_records_queued_span_at_claim_time():
    ctx = obs.mint()
    q = RequestQueue(registry=obs.MetricsRegistry(), prefix="daemon")
    r = _req(0, trace=ctx)
    q.put(r, SimFuture(r))
    time.sleep(0.01)
    q.drain(max_n=4, wait_s=0.0)
    spans = obs.TRACER.spans(ctx["trace_id"])
    assert [s["name"] for s in spans] == ["daemon.queued"]
    assert spans[0]["dur_s"] >= 0.01
    assert spans[0]["attrs"]["stream"] == "default"


def test_queue_submitted_wall_is_anchored_monotonic():
    """Clock discipline: ``submitted_at`` is monotonic-only; wall time
    is derived through the per-process anchor, never read per event."""
    r = _req(0)
    assert abs(r.submitted_at - time.monotonic()) < 1.0
    assert abs(r.submitted_wall - time.time()) < 1.0
    assert r.submitted_wall == pytest.approx(
        obs.clock.to_wall(r.submitted_at))


@pytest.mark.parametrize("stress_seed", [4321, 99])
def test_queue_metrics_concurrent_stress(stress_seed):
    """Instrumented-queue twin of the restore stress: racing drainers
    against a producer with restores mixed in, the wait histogram's
    count must equal total claims (each item observed exactly once per
    claim) and the live depth gauge must read 0 once everything
    settles — no lost or double-counted observations under real
    interleavings."""
    n = 200
    reg = obs.MetricsRegistry()
    q = RequestQueue(registry=reg, prefix="daemon")
    pairs = [(r := _req(i), SimFuture(r)) for i in range(n)]
    errors: list = []
    claims = [0]
    claims_lock = threading.Lock()

    def producer():
        prng = random.Random(stress_seed)
        try:
            for r, f in pairs:
                q.put(r, f)
                if prng.random() < 0.05:
                    time.sleep(0.0005)
        except Exception as exc:        # noqa: BLE001
            errors.append(exc)

    def drainer(seed):
        prng = random.Random(seed)
        try:
            while not all(f.done() for _, f in pairs):
                batch = q.drain(max_n=prng.randint(1, 7), wait_s=0.005)
                with claims_lock:
                    claims[0] += len(batch)
                if not batch:
                    continue
                if prng.random() < 0.3:
                    q.restore(batch)    # back for a later (re-counted) claim
                else:
                    for _, f in batch:
                        f.set_result("served")
        except Exception as exc:        # noqa: BLE001
            errors.append(exc)

    threads = [threading.Thread(target=producer)]
    threads += [threading.Thread(target=drainer, args=(stress_seed + i,))
                for i in range(4)]
    for t in threads:
        t.start()
    threads[0].join(timeout=60.0)
    q.close()
    for t in threads:
        t.join(timeout=60.0)
    assert not any(t.is_alive() for t in threads), "stress wedged"
    assert not errors, errors
    snap = reg.snapshot()
    # every claim observed exactly once — restores produce a fresh
    # observation on the next claim, by design (time-in-queue per stint)
    assert snap["histograms"]["daemon.queue.wait_s"]["count"] == claims[0]
    assert claims[0] >= n
    assert snap["gauges"]["daemon.queue.depth"] == 0


# ---------------------------------------------------------------------------
# in-process serve: legacy stats shape, timeline, and THE bit-equality pin
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def stream_arrays():
    rng = np.random.default_rng(0)
    K, n = 4, 64
    return (rng.normal(size=(K, n)), rng.normal(size=n),
            np.abs(rng.normal(size=K)) + 0.1)


def test_server_stats_keeps_legacy_keys_and_grows_registry(stream_arrays):
    from repro.serve import SimClient, SimServer
    preds, y, costs = stream_arrays
    with SimServer(max_batch=8, max_wait_ms=1.0) as srv:
        srv.register_stream("default", preds, y, costs)
        client = SimClient(srv)
        futs = [client.submit(algo="eflfg", seed=s, T=20) for s in range(4)]
        for f in futs:
            f.result(timeout=600.0)
        st = srv.stats()
        # the legacy flat shape every existing caller reads
        for key in ("submitted", "served", "failed", "batches",
                    "batched_lanes", "padded_lanes", "exact_requests",
                    "sharded_batches", "mean_occupancy", "cache"):
            assert key in st, key
        assert st["submitted"] == st["served"] == 4 and st["failed"] == 0
        # ... and the typed registry tree behind it agrees
        snap = srv.metrics.snapshot()
        assert snap["counters"]["server.submitted"] == 4
        assert snap["counters"]["server.served"] == 4
        assert snap["histograms"]["server.queue.wait_s"]["count"] == 4
        assert snap["histograms"]["server.dispatch_s"]["count"] >= 1


def test_traced_request_timeline_in_process(stream_arrays):
    from repro.serve import SimClient, SimServer
    preds, y, costs = stream_arrays
    with SimServer(max_batch=8, max_wait_ms=1.0) as srv:
        srv.register_stream("default", preds, y, costs)
        client = SimClient(srv)
        fut = client.submit(algo="eflfg", seed=1, T=20)
        fut.result(timeout=600.0)
        tid = fut.request.trace["trace_id"]
        spans = obs.TRACER.spans(tid)
        assert [s["name"] for s in spans] == \
            ["serve.submitted", "server.queued", "serve.dispatch"]
        dispatch = spans[-1]
        assert dispatch["attrs"]["outcome"] == "ok"
        assert dispatch["attrs"]["n_requests"] == 1
        assert 1 in dispatch["attrs"]["co_seeds"]


def test_wave_with_tracing_enabled_is_bit_equal_to_disabled(stream_arrays):
    """THE observe-only pin: identical request waves, tracing on vs
    off, must produce ``identical_to``-equal results lane for lane —
    telemetry can never move a bit (docs/observability.md)."""
    from repro.serve import SimClient, SimServer
    preds, y, costs = stream_arrays
    waves = {}
    for enabled in (True, False):
        with obs.scoped(enabled):
            with SimServer(max_batch=8, max_wait_ms=1.0) as srv:
                srv.register_stream("default", preds, y, costs)
                client = SimClient(srv)
                futs = [client.submit(algo=a, seed=s, T=30)
                        for a in ("eflfg", "fedboost") for s in range(3)]
                waves[enabled] = [f.result(timeout=600.0) for f in futs]
                if enabled:
                    assert all(f.request.trace for f in futs)
                else:
                    assert all(f.request.trace is None for f in futs)
    for lane, (on, off) in enumerate(zip(waves[True], waves[False])):
        assert on.identical_to(off), f"lane {lane} drifted"
