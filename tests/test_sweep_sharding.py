"""Mesh-sharded sweeps: the sharded ``run_sweep`` path must be bit-equal
to the single-device vmap path.

Two layers, mirroring tests/test_distribution.py:

* In-process (any device count): the sharded path on a trivial (1, 1)
  mesh — the full shard_map/padding/unpad machinery with no actual
  partitioning — plus the pure helpers (``pad_configs``, ``sweep_specs``
  validation, dispatch override).
* One subprocess with ``--xla_force_host_platform_device_count=8``
  running every multi-device equality check (non-divisible padding, the
  budget grid, auto-dispatch, and the 2-D ``(sweep, data)`` mesh with
  both the divisible-window gather path and the indivisible-window
  replicated fallback) and emitting one JSON record the tests assert on.

Equality discipline: the 1-D sweep mesh runs the *identical* per-config
program as the vmap path, so every comparison there is ``array_equal``
against the default (fused) engine.  The 2-D data-axis path necessarily
uses the unfused evaluation (the Pallas client-eval kernel is
single-device), so its bit-equality is pinned against the unfused vmap
path; vs the default fused path it inherits the fused-vs-unfused float32
tolerance of tests/test_client_eval.py (see docs/sweeps.md).
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.federated import SweepResult  # noqa: E402

FIELDS = SweepResult.FIELDS


# ---------------------------------------------------------------------------
# In-process: helpers + trivial-mesh sharded path (works on one device)
# ---------------------------------------------------------------------------

def _stream(K=8, n_stream=400, seed=0):
    rng = np.random.default_rng(seed)
    preds = rng.normal(0, 1, (K, n_stream)).astype(np.float32)
    y = rng.normal(0, 1, n_stream).astype(np.float32)
    costs = rng.uniform(0.1, 1.0, K).astype(np.float32)
    return preds, y, costs


def test_pad_configs():
    from repro.federated.sweep_sharding import pad_configs
    keys = jnp.stack([jax.random.PRNGKey(s) for s in range(5)])
    budgets = jnp.arange(5, dtype=jnp.float32)
    pk, pb = pad_configs(keys, budgets, 4)
    assert pk.shape == (8, 2) and pb.shape == (8,)
    np.testing.assert_array_equal(np.asarray(pk[:5]), np.asarray(keys))
    # padding repeats the last (valid) configuration
    np.testing.assert_array_equal(np.asarray(pk[5:]),
                                  np.tile(np.asarray(keys[-1]), (3, 1)))
    np.testing.assert_array_equal(np.asarray(pb[5:]), [4.0, 4.0, 4.0])
    # already divisible: unchanged objects
    pk2, pb2 = pad_configs(keys[:4], budgets[:4], 4)
    assert pk2.shape == (4, 2) and pb2.shape == (4,)


def test_sweep_specs_validation():
    from repro.launch.mesh import make_sweep_mesh
    from repro.launch.sharding import sweep_specs
    mesh = make_sweep_mesh()            # trivial on one device
    in_specs, out_spec = sweep_specs(mesh, n_configs=jax.device_count())
    assert len(in_specs) == 5
    bad = 3 * jax.device_count() + 1
    if jax.device_count() > 1:
        with pytest.raises(ValueError, match="pad"):
            sweep_specs(mesh, n_configs=bad)


def test_mesh_axes_rejects_foreign_mesh():
    from repro.federated.sweep_sharding import mesh_axes
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    with pytest.raises(ValueError, match="sweep"):
        mesh_axes(mesh)


def test_sharded_path_trivial_mesh_bit_equal():
    """Forcing the sharded path onto a TRIVIAL (1, 1) mesh must reproduce
    the vmap path bit-for-bit — the full shard_map/padding/unpadding
    machinery with no actual partitioning.

    The mesh is pinned to one device explicitly: under the forced-8 CI
    environment the default mesh would really partition 3 configs into
    width-1 shards, which execute the *solo* program family and are not
    bit-equal to the vmapped batch (docs/serving.md#determinism — the
    multi-device equality cases with width >= 2 live in this file's
    subprocess checks)."""
    from repro.federated import SimConfig, run_sweep
    from repro.launch.mesh import make_sweep_mesh
    preds, y, costs = _stream()
    cfg_v = SimConfig(budget=2.0, sweep_sharded=False)
    cfg = SimConfig(budget=2.0)
    trivial = make_sweep_mesh(devices=jax.devices()[:1])
    for algo in ("eflfg", "fedboost"):
        sv = run_sweep(algo, preds, y, costs, T=60, cfg=cfg_v,
                       seeds=range(3))
        ss = run_sweep(algo, preds, y, costs, T=60, cfg=cfg,
                       seeds=range(3), mesh=trivial)
        assert not sv.sharded and ss.sharded
        for f in FIELDS:
            np.testing.assert_array_equal(getattr(sv, f), getattr(ss, f),
                                          err_msg=f"{algo}/{f}")
    # grid layout must survive the flatten/unflatten round trip
    gv = run_sweep("eflfg", preds, y, costs, T=60, cfg=cfg_v,
                   seeds=range(3), budgets=[1.0, 2.0])
    gs = run_sweep("eflfg", preds, y, costs, T=60, cfg=cfg,
                   seeds=range(3), budgets=[1.0, 2.0], mesh=trivial)
    assert gs.mse_curves.shape == (2, 3, 60)
    for f in FIELDS:
        np.testing.assert_array_equal(getattr(gv, f), getattr(gs, f),
                                      err_msg=f"grid/{f}")


def test_dispatch_rules():
    from repro.federated.engine import _dispatch_sharded
    from repro.federated import SimConfig
    auto = SimConfig()
    assert _dispatch_sharded(auto, 8) == (jax.device_count() > 1)
    assert not _dispatch_sharded(auto, 1) or jax.device_count() == 1
    assert _dispatch_sharded(SimConfig(sweep_sharded=True), 1)
    assert not _dispatch_sharded(SimConfig(sweep_sharded=False), 8)


def test_explicit_mesh_forces_sharded_path():
    """A requested mesh is never silently ignored: it forces the sharded
    path, and conflicts with sweep_sharded=False loudly."""
    from repro.federated import SimConfig, run_sweep
    from repro.launch.mesh import make_sweep_mesh
    preds, y, costs = _stream()
    mesh = make_sweep_mesh()
    sw = run_sweep("eflfg", preds, y, costs, T=40, cfg=SimConfig(budget=2.0),
                   seeds=range(2), mesh=mesh)
    assert sw.sharded
    with pytest.raises(ValueError, match="sweep_sharded=False"):
        run_sweep("eflfg", preds, y, costs, T=40,
                  cfg=SimConfig(budget=2.0, sweep_sharded=False),
                  seeds=range(2), mesh=mesh)


# ---------------------------------------------------------------------------
# Subprocess: 8 forced host devices, real partitioning
# ---------------------------------------------------------------------------

_SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import json
from dataclasses import replace

import numpy as np
import jax

from repro.federated import SimConfig, run_sweep, run_sweep_sharded
from repro.launch.mesh import make_sweep_mesh

rng = np.random.default_rng(0)
preds = rng.normal(0, 1, (8, 400)).astype(np.float32)
y = rng.normal(0, 1, 400).astype(np.float32)
costs = rng.uniform(0.1, 1.0, 8).astype(np.float32)
T = 120

def eq(a, b):
    return a.identical_fields(b)

rec = {"devices": jax.device_count(), "checks": {}}
cfg = SimConfig(budget=2.0)
cfg_off = replace(cfg, sweep_sharded=False)

for algo in ("eflfg", "fedboost"):
    # 12 configs on 8 shards: padding + unpadding, bit-equal to vmap
    v = run_sweep(algo, preds, y, costs, T=T, cfg=cfg_off, seeds=range(12))
    s = run_sweep_sharded(algo, preds, y, costs, T=T, cfg=cfg,
                          seeds=range(12))
    rec["checks"][f"{algo}/seeds12_pad"] = eq(v, s)
    rec["checks"][f"{algo}/seeds12_flags"] = {"vmap_not_sharded":
                                              not v.sharded,
                                              "sharded_flag": s.sharded}

# auto-dispatch picks the sharded path on a multi-device host
auto = run_sweep("eflfg", preds, y, costs, T=T, cfg=cfg, seeds=range(12))
v = run_sweep("eflfg", preds, y, costs, T=T, cfg=cfg_off, seeds=range(12))
rec["checks"]["auto_dispatch"] = dict(eq(v, auto), sharded=auto.sharded)

# budget grid: 3 x 5 = 15 flat configs (again non-divisible)
gv = run_sweep("eflfg", preds, y, costs, T=T, cfg=cfg_off, seeds=range(5),
               budgets=[1.0, 2.0, 3.0])
gs = run_sweep_sharded("eflfg", preds, y, costs, T=T, cfg=cfg,
                       seeds=range(5), budgets=[1.0, 2.0, 3.0])
rec["checks"]["grid3x5_pad"] = dict(eq(gv, gs),
                                    shape_ok=gs.mse_curves.shape == (3, 5, T))

# 2-D (sweep=4, data=2) mesh, divisible window (W=6): the all-gather
# window path — bit-equal to the unfused vmap path (see module docstring)
mesh2 = make_sweep_mesh(n_data=2)
for algo in ("eflfg", "fedboost"):
    cfg6 = SimConfig(budget=2.0, clients_per_round=6, use_fused=False)
    v6 = run_sweep(algo, preds, y, costs, T=T,
                   cfg=replace(cfg6, sweep_sharded=False), seeds=range(12))
    s6 = run_sweep_sharded(algo, preds, y, costs, T=T, cfg=cfg6,
                           seeds=range(12), mesh=mesh2)
    rec["checks"][f"{algo}/mesh2d_gather"] = eq(v6, s6)

# 2-D mesh, indivisible window (W=5 on data=2): replicated fallback keeps
# the fused kernel, so it is bit-equal to the *default* vmap path
s5 = run_sweep_sharded("eflfg", preds, y, costs, T=T, cfg=cfg,
                       seeds=range(12), mesh=mesh2)
v5 = run_sweep("eflfg", preds, y, costs, T=T, cfg=cfg_off, seeds=range(12))
rec["checks"]["mesh2d_fallback_W5"] = eq(v5, s5)

# paper's uplink-bandwidth mode: W = n_clients = 20, divisible by data=2
cfgb = SimConfig(budget=2.0, uplink_bandwidth=12.0, loss_bandwidth=1.0,
                 n_clients=20, use_fused=False)
vb = run_sweep("eflfg", preds, y, costs, T=T,
               cfg=replace(cfgb, sweep_sharded=False), seeds=range(6))
sb = run_sweep_sharded("eflfg", preds, y, costs, T=T, cfg=cfgb,
                       seeds=range(6), mesh=mesh2)
rec["checks"]["mesh2d_bandwidth_mode"] = eq(vb, sb)

print(json.dumps(rec))
"""


@pytest.fixture(scope="module")
def sharded_record():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    p = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=540)
    assert p.returncode == 0, p.stderr[-3000:]
    return json.loads(p.stdout.strip().splitlines()[-1])


def _assert_all(check: dict, name: str):
    bad = [k for k, v in check.items() if not v]
    assert not bad, f"{name}: failed fields {bad} in {check}"


def test_subprocess_devices(sharded_record):
    assert sharded_record["devices"] == 8


@pytest.mark.parametrize("algo", ["eflfg", "fedboost"])
def test_sharded_bit_equal_with_padding(sharded_record, algo):
    """12 configs over 8 shards: padded, unpadded, bit-equal."""
    _assert_all(sharded_record["checks"][f"{algo}/seeds12_pad"],
                f"{algo}/seeds12_pad")
    _assert_all(sharded_record["checks"][f"{algo}/seeds12_flags"],
                f"{algo}/seeds12_flags")


def test_auto_dispatch_sharded(sharded_record):
    _assert_all(sharded_record["checks"]["auto_dispatch"], "auto_dispatch")


def test_grid_bit_equal_with_padding(sharded_record):
    _assert_all(sharded_record["checks"]["grid3x5_pad"], "grid3x5_pad")


@pytest.mark.parametrize("algo", ["eflfg", "fedboost"])
def test_mesh2d_gather_bit_equal(sharded_record, algo):
    """(sweep=4, data=2): all-gather window path vs unfused vmap path."""
    _assert_all(sharded_record["checks"][f"{algo}/mesh2d_gather"],
                f"{algo}/mesh2d_gather")


def test_mesh2d_indivisible_window_fallback(sharded_record):
    """W=5 doesn't divide data=2: replicated fallback stays on the fused
    kernel and matches the default vmap path bit-for-bit."""
    _assert_all(sharded_record["checks"]["mesh2d_fallback_W5"],
                "mesh2d_fallback_W5")


def test_mesh2d_bandwidth_mode(sharded_record):
    """The paper's N_t uplink formula (W = n_clients) through the 2-D
    gather path."""
    _assert_all(sharded_record["checks"]["mesh2d_bandwidth_mode"],
                "mesh2d_bandwidth_mode")
