"""End-to-end federated simulation: the paper's §IV claims, in miniature."""

import numpy as np

from repro.experts import pool_predict_all
from repro.federated import SimConfig, run_simulation


def _preds(small_pool):
    pool, xs, ys = small_pool
    return pool, pool_predict_all(pool, xs), ys


def test_eflfg_zero_budget_violations(small_pool):
    pool, preds, ys = _preds(small_pool)
    res = run_simulation("eflfg", preds, ys, pool.costs, T=120,
                         cfg=SimConfig(budget=2.0, seed=0))
    assert res.budget_violations == 0
    assert res.sel_sizes.min() >= 1
    assert np.isfinite(res.mse_curve).all()


def test_fedboost_violates_budget(small_pool):
    pool, preds, ys = _preds(small_pool)
    res = run_simulation("fedboost", preds, ys, pool.costs, T=120,
                         cfg=SimConfig(budget=2.0, seed=0))
    assert res.violation_frac > 0.02


def test_eflfg_not_worse_than_fedboost(small_pool):
    """Table I direction: EFL-FG's final MSE <= FedBoost's (margin for
    stochasticity)."""
    pool, preds, ys = _preds(small_pool)
    a = run_simulation("eflfg", preds, ys, pool.costs, T=250,
                       cfg=SimConfig(budget=2.0, seed=1))
    b = run_simulation("fedboost", preds, ys, pool.costs, T=250,
                       cfg=SimConfig(budget=2.0, seed=1))
    assert a.final_mse <= b.final_mse * 1.10


def test_bandwidth_formula_limits_clients(small_pool):
    pool, preds, ys = _preds(small_pool)
    res = run_simulation("eflfg", preds, ys, pool.costs, T=40,
                         cfg=SimConfig(budget=2.0, uplink_bandwidth=12.0,
                                       loss_bandwidth=1.0, seed=0))
    # N_t = floor(12 / (|S_t|+1)) <= 6 for |S_t| >= 1
    assert res.budget_violations == 0


def test_mse_metric_is_running_mean(small_pool):
    pool, preds, ys = _preds(small_pool)
    res = run_simulation("eflfg", preds, ys, pool.costs, T=60,
                         cfg=SimConfig(budget=2.0, seed=2))
    # running mean: t * MSE_t is non-decreasing cumulative sum of positives
    cum = res.mse_curve * np.arange(1, 61)
    assert (np.diff(cum) >= -1e-9).all()
