"""Batched-native Algorithm 1 + dominating set: the custom_vmap rules.

Pins the two contracts the de-lockstepped builder introduces:

* **Bit-equality**: ``vmap(feedback_graph)`` / ``vmap(dominating_set)``
  (the batched-native loops) produce exactly the bits of per-lane solo
  calls — adjacency, dominating set, AND the per-lane ``n_iters``
  diagnostic — across heterogeneous budgets, including lanes that
  converge immediately riding next to lanes needing the full K-1 trips.
* **Numerics**: the per-row eligible score shift fixes the
  ineligible-leader degeneracy at extreme weight spreads (regression vs
  the float64 NumPy oracle); the hypothesis twin lives in
  tests/test_feedback_graph.py.

No hypothesis dependency — this file must run on minimal installs and in
the pallas-interpret CI job's environment.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import feedback_graph, feedback_graph_np
from repro.core.domset import dominating_set, dominating_set_np
from repro.core.graph import row_log_weight_sums

K = 22


def _rand(seed, B=1):
    rng = np.random.default_rng(seed)
    log_w = jnp.asarray(rng.normal(-1.0, 1.5, (B, K)).astype(np.float32))
    costs = jnp.asarray(rng.uniform(0.05, 1.0, K).astype(np.float32))
    return log_w, costs


def _solo(log_w, costs, budget, lps):
    adj, it = feedback_graph(log_w, costs, budget, lps, with_iters=True)
    return np.asarray(adj), int(it)


@pytest.mark.parametrize("seed", [0, 3])
def test_vmap_bit_equal_to_solo_lanes_hetero_budgets(seed):
    """One flat batched dispatch == B independent solo calls, bit for bit,
    with budgets spanning converge-in-one-trip to needs-all-K-1-trips."""
    B = 8
    log_w, costs = _rand(seed, B)
    # lane 0: budget below any pairwise cost sum -> zero appends, 0 iters;
    # lane B-1: budget covers everything -> K-1 appends.
    budgets = jnp.asarray(
        np.concatenate([[0.05], np.linspace(1.0, 8.0, B - 2),
                        [float(np.sum(np.asarray(costs))) + 1.0]]),
        jnp.float32)
    lps = jnp.full((B, K), 1e30, jnp.float32)

    vfg = jax.jit(jax.vmap(
        lambda lw, b, lp: feedback_graph(lw, costs, b, lp, with_iters=True),
        in_axes=(0, 0, 0)))
    adj_b, it_b = jax.tree.map(np.asarray, vfg(log_w, budgets, lps))
    dom_b = np.asarray(jax.jit(jax.vmap(dominating_set))(jnp.asarray(adj_b)))

    iters = []
    for i in range(B):
        adj_s, it_s = _solo(log_w[i], costs, budgets[i], lps[i])
        assert (adj_b[i] == adj_s).all(), f"lane {i} adjacency diverged"
        assert int(it_b[i]) == it_s, f"lane {i} n_iters diverged"
        assert (dom_b[i] == np.asarray(dominating_set(adj_b[i]))).all()
        iters.append(it_s)
    # the spread this test is about: fast and slow lanes truly co-resident
    assert iters[0] == 0 and max(iters) >= 2


def test_nested_vmap_grid_bit_equal_to_solo():
    """budgets x seeds grid (vmap of vmap, the run_sweep shape) still hits
    the batched rule and matches solo lanes bit-for-bit."""
    n_b, n_s = 3, 4
    log_w, costs = _rand(7, n_s)
    budgets = jnp.asarray([1.0, 3.0, 9.0], jnp.float32)
    lps = jnp.full((K,), 1e30, jnp.float32)

    grid = jax.jit(jax.vmap(jax.vmap(
        lambda lw, b: feedback_graph(lw, costs, b, lps, with_iters=True),
        in_axes=(0, None)), in_axes=(None, 0)))
    adj_g, it_g = jax.tree.map(np.asarray, grid(log_w, budgets))
    assert adj_g.shape == (n_b, n_s, K, K)
    for bi in range(n_b):
        for si in range(n_s):
            adj_s, it_s = _solo(log_w[si], costs, budgets[bi], lps)
            assert (adj_g[bi, si] == adj_s).all()
            assert int(it_g[bi, si]) == it_s


def test_graph_iters_invariant_to_batch_composition():
    """A lane's n_iters (and bits) must not depend on its co-residents or
    the batch width — the invariance lockstep-waste accounting relies on."""
    log_w, costs = _rand(11, 4)
    lps = jnp.full((4, K), 1e30, jnp.float32)
    budgets = jnp.asarray([0.2, 2.0, 5.0, 30.0], jnp.float32)
    vfg = jax.jit(jax.vmap(
        lambda lw, b, lp: feedback_graph(lw, costs, b, lp, with_iters=True),
        in_axes=(0, 0, 0)))
    adj4, it4 = jax.tree.map(np.asarray, vfg(log_w, budgets, lps))
    # same lane pair embedded in a width-2 batch
    adj2, it2 = jax.tree.map(np.asarray,
                             vfg(log_w[1:3], budgets[1:3], lps[1:3]))
    assert (adj4[1:3] == adj2).all() and (it4[1:3] == it2).all()
    for i in range(4):
        adj_s, it_s = _solo(log_w[i], costs, budgets[i], lps[i])
        assert (adj4[i] == adj_s).all() and int(it4[i]) == it_s


def test_ineligible_leader_extreme_spread_matches_oracle():
    """Regression for the per-row eligible score shift (see
    graph.feedback_graph's precision note): an unaffordable leader 120
    nats above every eligible candidate used to underflow their scores to
    a lowest-index tie; now the trajectory matches the float64 oracle."""
    for seed in range(20):
        r = np.random.default_rng(seed)
        Kk = 10
        lw = np.zeros(Kk)
        lw[1:] = -120.0 + r.uniform(0.0, 5.0, Kk - 1)
        c = np.empty(Kk)
        c[0] = 10.0
        c[1:] = r.uniform(0.1, 1.0, Kk - 1)
        adj = np.asarray(feedback_graph(jnp.asarray(lw, jnp.float32),
                                        jnp.asarray(c, jnp.float32),
                                        jnp.float32(3.0),
                                        jnp.full((Kk,), 1e30)))
        adj_np = feedback_graph_np(np.exp(lw), c, 3.0, np.full(Kk, 1e30))
        assert (adj == adj_np).all(), f"seed {seed}"


def test_batched_oracle_agreement_random_cases():
    """vmapped builder vs the literal NumPy transcription across random
    sizes (moderate spreads: the regime every sweep actually runs in)."""
    for seed in range(25):
        r = np.random.default_rng(seed)
        Kk = int(r.integers(3, 12))
        w = r.uniform(0.05, 1.0, Kk)
        c = r.uniform(0.05, 1.0, Kk)
        bud = float(r.uniform(1.0, 4.0) * c.max())
        lw = jnp.asarray(np.log(w), jnp.float32)
        cj = jnp.asarray(c, jnp.float32)
        lps = jnp.full((Kk,), 1e30, jnp.float32)
        adj_b = np.asarray(jax.vmap(
            lambda l: feedback_graph(l, cj, jnp.float32(bud), lps)
        )(jnp.stack([lw, lw])))
        adj_np = feedback_graph_np(w, c, bud, np.full(Kk, 1e30))
        assert (adj_b[0] == adj_np).all() and (adj_b[1] == adj_np).all()


def test_domset_vmap_bit_equal_and_oracle():
    for seed in range(10):
        rng = np.random.default_rng(seed)
        adj = rng.random((6, K, K)) < 0.25
        adj |= np.eye(K, dtype=bool)[None]
        adj_j = jnp.asarray(adj)
        dom_b = np.asarray(jax.jit(jax.vmap(dominating_set))(adj_j))
        for i in range(6):
            dom_s = np.asarray(dominating_set(adj_j[i]))
            assert (dom_b[i] == dom_s).all()
            assert (dom_s == dominating_set_np(adj[i])).all()
            assert adj[i][dom_b[i]].any(axis=0).all()   # actually dominates


def test_round_trip_trajectory_vmap_equals_solo():
    """300-round graph+domset+weight-update trajectory: the full recurrent
    composition the engine runs, vmapped vs per-lane solo, bit-equal."""
    T, B = 120, 4
    log_w0, costs = _rand(5, B)
    budgets = jnp.asarray([1.0, 2.5, 4.0, 8.0], jnp.float32)

    def roll(log_w, bud, batched):
        def body(carry, _):
            lw, lps = carry
            adj, it = feedback_graph(lw, costs, bud, lps, with_iters=True)
            dom = dominating_set(adj)
            lw = lw - 0.01 * (jnp.sum(adj, -1) + dom).astype(jnp.float32)
            lps = (jax.vmap(row_log_weight_sums)(adj, lw) if batched
                   else row_log_weight_sums(adj, lw))
            return (lw, lps), (adj, dom, it)
        shape = log_w.shape
        _, outs = jax.lax.scan(body, (log_w, jnp.full(shape, 1e30)), None,
                               length=T)
        return outs

    batched = jax.jit(jax.vmap(lambda lw, b: roll(lw, b, False),
                               in_axes=(0, 0)))
    o_b = jax.tree.map(np.asarray, batched(log_w0, budgets))
    for i in range(B):
        o_s = jax.tree.map(np.asarray,
                           jax.jit(lambda lw, b: roll(lw, b, False))(
                               log_w0[i], budgets[i]))
        for got, want, name in zip((a[i] for a in o_b), o_s,
                                   ("adj", "dom", "iters")):
            assert (got == want).all(), f"lane {i} {name}"
