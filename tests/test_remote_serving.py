"""Remote-vs-local determinism: the serving contract across the wire.

The equivalence map rows pinned here (docs/determinism.md):

* a **batched** wave submitted through ``SimClient.connect`` (daemon ->
  worker subprocess -> ``run_batch``) is bit-equal, lane for lane, to
  the same wave through an in-process ``SimServer`` AND to a direct
  ``run_batch`` call — the remote hop adds serialization, never ulps;
* **exact**-mode remote submits are bit-equal to direct
  ``run_simulation_scan`` runs — the reproducibility mode survives the
  process boundary.

The wave is the paper configuration (K=22 experts, n_stream=6000,
T=2000) with mixed seeds, budgets and scenarios — 8 requests, enough
for a scheduled and a stationary bucket of width >= 2 each.
"""

from __future__ import annotations

from dataclasses import replace

import numpy as np
import pytest

from repro.federated import (SimConfig, run_batch, run_simulation_scan)
from repro.serve import SimClient
from repro.serve.daemon import ServeDaemon
from repro.serve.server import SimServer

K, N_STREAM, T = 22, 6000, 2000

# 8 mixed-seed/budget/scenario paper-config requests: 4 stationary
# lanes + 4 scheduled lanes (two distinct schedules), mixed budgets
# with None = config default
WAVE = [
    dict(algo="eflfg", seed=0, T=T, budget=None, scenario=None),
    dict(algo="eflfg", seed=1, T=T, budget=2.0, scenario=None),
    dict(algo="eflfg", seed=2, T=T, budget=4.0, scenario=None),
    dict(algo="eflfg", seed=3, T=T, budget=3.0, scenario=None),
    dict(algo="eflfg", seed=4, T=T, budget=None,
         scenario="concept_drift"),
    dict(algo="eflfg", seed=5, T=T, budget=2.0,
         scenario="concept_drift"),
    dict(algo="eflfg", seed=6, T=T, budget=4.0,
         scenario="degraded_uplink"),
    dict(algo="eflfg", seed=7, T=T, budget=3.0,
         scenario="degraded_uplink"),
]


@pytest.fixture(scope="module")
def stream_arrays():
    rng = np.random.default_rng(0)
    preds = rng.normal(0.0, 1.0, (K, N_STREAM)).astype(np.float32)
    y = rng.normal(0.0, 1.0, N_STREAM).astype(np.float32)
    costs = rng.uniform(0.5, 2.0, K).astype(np.float32)
    return preds, y, costs


@pytest.fixture(scope="module")
def remote(stream_arrays):
    daemon = ServeDaemon(max_pending=64, retry_limit=1,
                         worker_args={"max_batch": 16,
                                      "max_wait_ms": 2.0})
    daemon.start()
    client = SimClient.connect(daemon.addr)
    client.server.register_stream("default", *stream_arrays)
    yield client
    client.close()
    daemon.drain_and_stop()


@pytest.fixture(scope="module")
def remote_batched(remote):
    futs = [remote.submit(**spec) for spec in WAVE]
    return [f.result(timeout=600.0) for f in futs], futs


def test_remote_wave_is_batched_family(remote_batched):
    results, futs = remote_batched
    assert len(results) == len(WAVE)
    for fut in futs:
        assert fut.execution["mode"] == "batched"
        assert fut.execution["bucket"] >= 2    # width never 1: family rule


def test_remote_batched_bit_equal_to_run_batch(remote_batched,
                                               stream_arrays):
    """Each remote lane vs a direct ``run_batch`` of its schedule-class
    group — the grouping the batcher itself dispatches (stationary
    lanes must ride the scenario-free program, never a neutral-fed
    scheduled one: docs/determinism.md rows 14-16)."""
    preds, y, costs = stream_arrays
    results, _ = remote_batched
    cfg = SimConfig()
    for group in (range(0, 4), range(4, 8)):        # stationary, scheduled
        specs = [WAVE[i] for i in group]
        seeds = [s["seed"] for s in specs]
        budgets = [s["budget"] if s["budget"] is not None else cfg.budget
                   for s in specs]
        scenarios = [s["scenario"] for s in specs]
        scenario = (None if all(sc is None for sc in scenarios)
                    else scenarios)
        local = run_batch("eflfg", preds, y, costs, T, cfg, seeds,
                          budgets, scenario=scenario)
        for i, local_res in zip(group, local):
            assert results[i].identical_to(local_res), \
                (i, results[i].identical_fields(local_res))


def test_remote_batched_bit_equal_to_inprocess_simserver(remote_batched,
                                                         stream_arrays):
    results, _ = remote_batched
    with SimServer(max_batch=16, max_wait_ms=2.0) as server:
        server.register_stream("default", *stream_arrays)
        local_futs = [server.submit(**spec) for spec in WAVE]
        local = [f.result(timeout=600.0) for f in local_futs]
    for i, (remote_res, local_res) in enumerate(zip(results, local)):
        assert remote_res.identical_to(local_res), \
            (i, remote_res.identical_fields(local_res))


def test_remote_exact_bit_equal_to_direct_scans(remote, stream_arrays):
    preds, y, costs = stream_arrays
    futs = [remote.submit(**spec, exact=True) for spec in WAVE]
    results = [f.result(timeout=600.0) for f in futs]
    for fut in futs:
        assert fut.execution["mode"] == "exact"
    cfg = SimConfig()
    for spec, remote_res in zip(WAVE, results):
        budget = (spec["budget"] if spec["budget"] is not None
                  else cfg.budget)
        direct = run_simulation_scan(
            spec["algo"], preds, y, costs, T,
            replace(cfg, seed=spec["seed"], budget=budget),
            scenario=spec["scenario"])
        assert remote_res.identical_to(direct), \
            (spec, remote_res.identical_fields(direct))


def test_pool_any_worker_bit_equal_to_single_worker(remote_batched,
                                                    stream_arrays):
    """The SAME wave through a ``--workers 2`` pool daemon, once per
    pool slot: two stream names with different rendezvous homes carry
    identical arrays, so each worker subprocess serves the full wave —
    and every lane is bit-equal to the single-worker daemon's (which
    rows 19-20 already pin to the in-process server and direct
    ``run_batch``).  Routing NEVER changes bits: any worker ==
    single worker == in-process (docs/determinism.md row 21)."""
    from repro.serve import router

    reference, _ = remote_batched
    names = (f"mirror{i}" for i in range(100))
    name0 = next(n for n in names if router.affine_worker(n, 1, [0, 1]) == 0)
    name1 = next(n for n in names if router.affine_worker(n, 1, [0, 1]) == 1)
    pool = ServeDaemon(workers=2, max_pending=64, retry_limit=1,
                       worker_args={"max_batch": 16, "max_wait_ms": 2.0})
    pool.start()
    client = SimClient.connect(pool.addr)
    try:
        client.server.register_stream(name0, *stream_arrays)
        client.server.register_stream(name1, *stream_arrays)
        served_by = {}
        for name in (name0, name1):
            futs = [client.submit(**spec, stream=name) for spec in WAVE]
            results = [f.result(timeout=600.0) for f in futs]
            workers = {f.execution["worker"] for f in futs}
            assert len(workers) == 1        # affinity kept the wave home
            served_by[name] = workers.pop()
            for i, (got, want) in enumerate(zip(results, reference)):
                assert got.identical_to(want), \
                    (name, i, got.identical_fields(want))
        # the two waves really ran on two distinct worker subprocesses
        assert served_by[name0] != served_by[name1]
        st = pool.status()
        assert st["counters"]["spilled"] == 0
    finally:
        client.close()
        pool.drain_and_stop()


def test_remote_result_surface_is_complete(remote_batched):
    """The wire carries the full SimResult surface: curves, selection
    masks, violation counts and a regret tracker whose curve is usable
    post-hoc."""
    results, _ = remote_batched
    res = results[0]
    assert res.mse_curve.shape == (T,)
    assert res.sel_masks is not None and res.sel_masks.shape == (T, K)
    assert res.regret.regret_curve().shape == (T,)
    assert 0.0 <= res.violation_frac <= 1.0
    assert res.final_mse == float(res.mse_curve[-1])
