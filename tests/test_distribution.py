"""Distribution tests: sharding rules (pure), and a reduced-mesh dry-run in
a subprocess with 8 forced host devices (the miniature of deliverable e)."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_param_specs_divisibility_guard():
    from repro.launch.sharding import param_specs
    from jax.sharding import PartitionSpec as P
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    # fake mesh with axis sizes 1 never rejects; use a shape-only check via
    # a synthetic mesh object is not possible -> use the real guard through
    # shapes divisible/indivisible by 1 (trivially divisible).  The real
    # divisibility behaviour is covered in the subprocess test below.
    shapes = {"wq": jax.ShapeDtypeStruct((8, 16), jnp.float32),
              "ln1": jax.ShapeDtypeStruct((8,), jnp.float32)}
    specs = param_specs(shapes, mesh)
    assert specs["wq"] == P(None, "model")
    assert specs["ln1"] == P()


def test_cache_spec_names():
    from repro.launch.sharding import cache_specs
    from jax.sharding import PartitionSpec as P
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    shapes = {
        "k": jax.ShapeDtypeStruct((4, 2, 64, 1, 8), jnp.float32),
        "v": jax.ShapeDtypeStruct((4, 2, 64, 1, 8), jnp.float32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    specs = cache_specs(shapes, mesh)
    assert specs["pos"] == P()
    # batch dim (=2, divisible by 1) sharded over data, kv heads over model
    assert specs["k"][1] in ("data", ("data",))
    assert specs["k"][3] == "model"


_SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
import dataclasses

from repro.launch import sharding as sh
from repro.models import get_config, model
from repro.optim import AdamWConfig, make_train_step, init_train_state
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import set_global_mesh, as_shardings

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
set_global_mesh(mesh)

cfg = get_config("qwen3-1.7b").reduced(n_layers=2, vocab_size=2048,
                                       d_model=256, n_heads=4, n_kv_heads=2)
key = jax.random.PRNGKey(0)
p_shapes = jax.eval_shape(lambda k: model.init_params(cfg, k, jnp.bfloat16), key)
pspecs = sh.param_specs(p_shapes, mesh)
opt_cfg = AdamWConfig()

class B:
    pass

def loss(params, b):
    return model.loss_fn(cfg, params, b)

from typing import NamedTuple
class Batch(NamedTuple):
    tokens: object
    targets: object
    mask: object

step = make_train_step(lambda p, b: model.loss_fn(cfg, p, Batch(*b)),
                       opt_cfg, accum_steps=2,
                       microbatch_spec=P(("pod", "data")))
state_shapes = jax.eval_shape(
    lambda k: init_train_state(model.init_params(cfg, k, jnp.bfloat16),
                               opt_cfg), key)
sspecs = sh.train_state_specs(state_shapes, pspecs)
batch = tuple(jax.ShapeDtypeStruct((16, 64), d)
              for d in (jnp.int32, jnp.int32, jnp.float32))
bspecs = sh.batch_specs(batch, mesh)
lowered = jax.jit(step, in_shardings=as_shardings(mesh, (sspecs, bspecs)),
                  out_shardings=as_shardings(mesh, (sspecs, None))
                  ).lower(state_shapes, batch)
compiled = lowered.compile()
from repro.launch.compat import cost_analysis_dict

ma = compiled.memory_analysis()
ca = cost_analysis_dict(compiled)
print(json.dumps({
    "ok": True,
    "devices": jax.device_count(),
    "temp": int(ma.temp_size_in_bytes),
    "flops": float(ca.get("flops", 0)),
}))
"""


def test_multipod_reduced_dryrun_subprocess():
    """Lower + compile a reduced train step on a (pod, data, model) mesh of
    8 forced host devices — validates mesh/specs end to end without the
    512-device production compile."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    # forced host devices only exist on the CPU platform; pinning it also
    # skips the (slow, sandbox-hostile) accelerator backend probe.
    env["JAX_PLATFORMS"] = "cpu"
    p = subprocess.run([sys.executable, "-c", _SUBPROCESS_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=420)
    assert p.returncode == 0, p.stderr[-3000:]
    rec = json.loads(p.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["devices"] == 8
    assert rec["flops"] > 0


def test_production_dryrun_artifacts_if_present():
    """When the full sweep has run (experiments/dryrun), every pair must
    have succeeded on both meshes."""
    d = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
    if not os.path.isdir(d) or not os.listdir(d):
        pytest.skip("production dry-run artifacts not generated yet")
    recs = []
    for f in os.listdir(d):
        if f.endswith(".json"):
            recs.append(json.load(open(os.path.join(d, f))))
    assert recs
    for r in recs:
        assert r.get("ok"), f"{r.get('arch')}/{r.get('shape')}/{r.get('mesh')}"
