"""Scan engine vs reference loop: trajectories must match, sweeps must be
deterministic, and the shared round body must match independent oracles.

Two layers of defense:

* The engine runs the same round body as the reference loop (built by
  ``make_round_body``), so equivalence between the two execution paths is
  expected to be *bit-exact* for the selection masks and within float
  tolerance for every curve — across algos, seeds, and both client-count
  modes (fixed N_t and the paper's uplink bandwidth formula).
* Because that shared body makes the two paths equivalent by
  construction, the body's client-side *semantics* are additionally
  pinned against independent host-side float64 NumPy implementations
  (the pre-engine ``_client_losses`` / ``_clients_for_round`` logic,
  resurrected here as test oracles)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.federated import (SimConfig, run_simulation_reference,
                             run_simulation_scan, run_sweep)
from repro.federated.simulation import (client_window_losses,
                                        fedboost_window_grad,
                                        n_clients_traceable)


def _stream(K=8, n_stream=400, seed=0):
    rng = np.random.default_rng(seed)
    preds = rng.normal(0, 1, (K, n_stream)).astype(np.float32)
    y = rng.normal(0, 1, n_stream).astype(np.float32)
    costs = rng.uniform(0.1, 1.0, K).astype(np.float32)
    return preds, y, costs


@pytest.mark.parametrize("algo", ["eflfg", "fedboost"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_scan_matches_reference(algo, seed):
    preds, y, costs = _stream()
    cfg = SimConfig(budget=2.0, seed=seed)
    T = 150
    ref = run_simulation_reference(algo, preds, y, costs, T=T, cfg=cfg)
    eng = run_simulation_scan(algo, preds, y, costs, T=T, cfg=cfg)
    np.testing.assert_array_equal(ref.sel_masks, eng.sel_masks)
    np.testing.assert_array_equal(ref.sel_sizes, eng.sel_sizes)
    np.testing.assert_array_equal(ref.dom_sizes, eng.dom_sizes)
    np.testing.assert_allclose(ref.mse_curve, eng.mse_curve, atol=1e-5)
    np.testing.assert_allclose(ref.regret.regret_curve(),
                               eng.regret.regret_curve(), atol=1e-5)
    np.testing.assert_allclose(ref.round_costs, eng.round_costs, atol=1e-5)
    assert ref.budget_violations == eng.budget_violations
    assert ref.regret.best_model() == eng.regret.best_model()


@pytest.mark.parametrize("algo", ["eflfg", "fedboost"])
def test_scan_matches_reference_bandwidth_mode(algo):
    """The uplink formula N_t = floor(b / (b_loss (|S_t|+1))) makes the
    client count data dependent — the fixed-window masking must still
    reproduce the reference exactly."""
    preds, y, costs = _stream(seed=3)
    cfg = SimConfig(budget=2.0, uplink_bandwidth=12.0, loss_bandwidth=1.0,
                    n_clients=20, seed=0)
    T = 120
    ref = run_simulation_reference(algo, preds, y, costs, T=T, cfg=cfg)
    eng = run_simulation_scan(algo, preds, y, costs, T=T, cfg=cfg)
    np.testing.assert_array_equal(ref.sel_masks, eng.sel_masks)
    np.testing.assert_allclose(ref.mse_curve, eng.mse_curve, atol=1e-5)
    np.testing.assert_allclose(ref.regret.regret_curve(),
                               eng.regret.regret_curve(), atol=1e-5)
    assert ref.budget_violations == eng.budget_violations


def test_scan_matches_reference_on_expert_pool(small_pool):
    """End to end on real (kernel + MLP) experts, not synthetic streams."""
    from repro.experts import pool_predict_all
    pool, xs, ys = small_pool
    preds = pool_predict_all(pool, xs)
    cfg = SimConfig(budget=2.0, seed=0)
    ref = run_simulation_reference("eflfg", preds, ys, pool.costs, T=100,
                                   cfg=cfg)
    eng = run_simulation_scan("eflfg", preds, ys, pool.costs, T=100, cfg=cfg)
    np.testing.assert_array_equal(ref.sel_masks, eng.sel_masks)
    np.testing.assert_allclose(ref.mse_curve, eng.mse_curve, atol=1e-5)


def _client_losses_np(preds, y, cursor, n_t, mix, loss_scale):
    """Independent float64 host oracle: the pre-engine client evaluation
    (dynamic-size slice, no fixed window/masking)."""
    n_stream = preds.shape[1]
    idx = np.arange(cursor, cursor + n_t) % n_stream
    p_cl = preds[:, idx].astype(np.float64)
    y_cl = y[idx].astype(np.float64)
    sq = (p_cl - y_cl[None, :]) ** 2
    model_losses = np.minimum(sq / loss_scale, 1.0).sum(1)
    yhat = mix.astype(np.float64) @ p_cl
    ens_sq = (yhat - y_cl) ** 2
    return (ens_sq.mean(), np.minimum(ens_sq / loss_scale, 1.0).sum(),
            model_losses)


def test_window_losses_match_host_oracle():
    """The fixed-window masked evaluation must agree with the dynamic
    float64 NumPy implementation for every n_t <= window."""
    rng = np.random.default_rng(7)
    K, n_stream, window, loss_scale = 7, 53, 12, 4.0
    preds = rng.normal(0, 1, (K, n_stream)).astype(np.float32)
    y = rng.normal(0, 1, n_stream).astype(np.float32)
    for trial in range(30):
        cursor = int(rng.integers(0, n_stream))
        n_t = int(rng.integers(1, window + 1))
        mix = rng.dirichlet(np.ones(K)).astype(np.float32)
        ens_sq, ens_norm, ml = client_window_losses(
            jnp.asarray(preds), jnp.asarray(y), jnp.int32(cursor),
            jnp.int32(n_t), jnp.asarray(mix), loss_scale, window)
        o_sq, o_norm, o_ml = _client_losses_np(preds, y, cursor, n_t, mix,
                                               loss_scale)
        np.testing.assert_allclose(float(ens_sq), o_sq, rtol=1e-5)
        np.testing.assert_allclose(float(ens_norm), o_norm, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(ml), o_ml, rtol=1e-5,
                                   atol=1e-6)


def test_fedboost_grad_matches_host_oracle():
    """g_k = 2/n sum_i (yhat - y) f_k(x_i) over the round's n_t samples."""
    rng = np.random.default_rng(8)
    K, n_stream, window = 5, 40, 9
    preds = rng.normal(0, 1, (K, n_stream)).astype(np.float32)
    y = rng.normal(0, 1, n_stream).astype(np.float32)
    for trial in range(20):
        cursor = int(rng.integers(0, n_stream))
        n_t = int(rng.integers(1, window + 1))
        mix = rng.dirichlet(np.ones(K)).astype(np.float32)
        g = fedboost_window_grad(jnp.asarray(preds), jnp.asarray(y),
                                 jnp.int32(cursor), jnp.int32(n_t),
                                 jnp.asarray(mix), window)
        idx = np.arange(cursor, cursor + n_t) % n_stream
        p_cl = preds[:, idx].astype(np.float64)
        y_cl = y[idx].astype(np.float64)
        resid = mix.astype(np.float64) @ p_cl - y_cl
        oracle = (2.0 / n_t) * (p_cl @ resid)
        np.testing.assert_allclose(np.asarray(g), oracle, rtol=1e-4,
                                   atol=1e-6)


def test_bandwidth_formula_matches_host_oracle():
    """N_t = clip(floor(b / (b_loss (|S|+1))), 1, n_clients), against the
    pre-engine integer host computation (allowing the one-ulp float32
    boundary where floor(x) legitimately differs from float64)."""
    rng = np.random.default_rng(9)
    hits = 0
    for trial in range(500):
        b = float(rng.uniform(0.5, 60.0))
        bl = float(rng.uniform(0.2, 3.0))
        sel = int(rng.integers(0, 15))
        cfg = SimConfig(uplink_bandwidth=b, loss_bandwidth=bl, n_clients=30)
        n = int(n_clients_traceable(cfg, jnp.int32(sel)))
        oracle = max(1, min(int(b // (bl * (sel + 1))), cfg.n_clients))
        assert abs(n - oracle) <= 1, (b, bl, sel, n, oracle)
        hits += n == oracle
    assert hits >= 490   # exact agreement away from float boundaries


def test_sweep_shapes_and_determinism():
    preds, y, costs = _stream()
    cfg = SimConfig(budget=2.0)
    T, seeds = 80, [0, 1, 2, 3]
    a = run_sweep("eflfg", preds, y, costs, T=T, cfg=cfg, seeds=seeds)
    b = run_sweep("eflfg", preds, y, costs, T=T, cfg=cfg, seeds=seeds)
    assert a.mse_curves.shape == (4, T)
    assert a.regret_curves.shape == (4, T)
    assert a.sel_sizes.shape == (4, T)
    assert a.violations.shape == (4,)
    assert np.isfinite(a.mse_curves).all()
    # one compiled program, fixed seeds => bitwise reproducible
    np.testing.assert_array_equal(a.mse_curves, b.mse_curves)
    np.testing.assert_array_equal(a.regret_curves, b.regret_curves)
    np.testing.assert_array_equal(a.sel_sizes, b.sel_sizes)
    # distinct seeds actually produce distinct trajectories
    assert not np.array_equal(a.sel_sizes[0], a.sel_sizes[1])


def test_sweep_budget_grid():
    preds, y, costs = _stream()
    cfg = SimConfig()
    sw = run_sweep("eflfg", preds, y, costs, T=60, cfg=cfg, seeds=[0, 1],
                   budgets=[1.0, 2.0, 4.0])
    assert sw.mse_curves.shape == (3, 2, 60)
    assert sw.violations.shape == (3, 2)
    # EFL-FG holds the hard per-round budget at every grid point
    assert (sw.round_costs <= np.array([1.0, 2.0, 4.0])[:, None, None]
            + 1e-5).all()
    # larger budgets admit (weakly) larger transmit sets on average
    mean_sel = sw.sel_sizes.mean(axis=(1, 2))
    assert mean_sel[0] <= mean_sel[-1] + 1e-9
