"""Wire-codec and framing tests for ``repro.serve.transport``.

Deterministic tests cover the framing state machine (magic, codec tag,
length bound, the truncation-vs-clean-close distinction) and the tagged
ndarray round trip the remote determinism contract rests on.  The
Hypothesis twin — arbitrary request/response trees, truncation at every
drawn cut point — lives in ``tests/test_transport_codec_props.py``
(importorskip-guarded, like the repo's other property suites); this
file must run on minimal installs.
"""

from __future__ import annotations

import json
import math
import socket
import struct

import numpy as np
import pytest

from repro.serve import transport as tp


def _codecs():
    out = ["json"]
    if tp.default_codec() == "msgpack":
        out.append("msgpack")
    return out


def _eq(a, b) -> bool:
    """Round-trip equality: arrays bit-for-bit, NaN == NaN, tuples
    normalize to lists."""
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        return (isinstance(a, np.ndarray) and isinstance(b, np.ndarray)
                and a.dtype == b.dtype and a.shape == b.shape
                and a.tobytes() == b.tobytes())
    if isinstance(a, dict):
        return (isinstance(b, dict) and a.keys() == b.keys()
                and all(_eq(v, b[k]) for k, v in a.items()))
    if isinstance(a, (list, tuple)):
        return (isinstance(b, (list, tuple)) and len(a) == len(b)
                and all(_eq(x, y) for x, y in zip(a, b)))
    if isinstance(a, float) and isinstance(b, float):
        return (math.isnan(a) and math.isnan(b)) or a == b
    return type(a) is type(b) and a == b


def _roundtrip(tree, codec):
    c, payload = tp.encode(tree, codec)
    assert c == codec
    return tp.decode(c, payload)


def _feed(data: bytes) -> socket.socket:
    """A socket whose read side sees exactly ``data`` then EOF."""
    a, b = socket.socketpair()
    a.sendall(data)
    a.close()
    return b


# ---------------------------------------------------------------------------
# deterministic codec round trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", _codecs())
def test_roundtrip_request_shaped_tree(codec):
    tree = {"id": 17, "method": "submit",
            "params": {"algo": "eflfg", "seed": 3, "T": 2000,
                       "budget": None, "exact": False,
                       "cfg": {"eta": 0.125, "xi": None},
                       "scenario": "concept_drift"},
            "deadline_ms": 1500.0}
    assert _eq(_roundtrip(tree, codec), tree)


@pytest.mark.parametrize("codec", _codecs())
@pytest.mark.parametrize("dtype", ["float32", "float64", "int32", "bool"])
def test_roundtrip_arrays_bit_exact(codec, dtype):
    rng = np.random.default_rng(0)
    arr = rng.normal(0, 1, (3, 5)).astype(dtype)
    out = _roundtrip({"arr": arr}, codec)["arr"]
    assert out.dtype == arr.dtype and out.shape == arr.shape
    assert out.tobytes() == arr.tobytes()


@pytest.mark.parametrize("codec", _codecs())
def test_roundtrip_nan_inf_and_signalling_bits(codec):
    # distinct NaN payload bits must survive: the arrays ride as raw
    # bytes, so even non-default NaNs are preserved exactly
    raw = np.array([0x7fc00001, 0x7f800000, 0xff800000, 0x80000000],
                   dtype=np.uint32)
    arr = raw.view(np.float32)
    out = _roundtrip({"x": arr, "scalars": [float("nan"), float("inf"),
                                            -float("inf"), -0.0]}, codec)
    assert out["x"].tobytes() == arr.tobytes()
    s = out["scalars"]
    assert math.isnan(s[0]) and s[1] == math.inf and s[2] == -math.inf
    assert math.copysign(1.0, s[3]) == -1.0


@pytest.mark.parametrize("codec", _codecs())
def test_roundtrip_zero_length_stream_and_bytes(codec):
    tree = {"empty": np.zeros((0,), np.float32),
            "empty2d": np.zeros((4, 0), np.float64),
            "blob": b"\x00\xff\xa5", "nothing": b"", "text": ""}
    out = _roundtrip(tree, codec)
    assert out["empty"].shape == (0,) and out["empty"].dtype == np.float32
    assert out["empty2d"].shape == (4, 0)
    assert out["blob"] == b"\x00\xff\xa5" and out["nothing"] == b""


def test_tuples_normalize_to_lists():
    out = _roundtrip({"t": (1, 2, (3, 4))}, "json")
    assert out["t"] == [1, 2, [3, 4]]


def test_unencodable_object_raises_typerror():
    with pytest.raises(TypeError):
        tp.encode({"x": object()}, "json")
    with pytest.raises(TypeError):
        tp.encode({1: "non-string key"}, "json")


def test_error_wire_roundtrip_typed():
    for exc_type in (tp.Overloaded, tp.DeadlineExceeded, tp.WorkerDied,
                     tp.ConnectionLost, tp.FrameError, ValueError):
        back = tp.error_from_wire(tp.error_to_wire(exc_type("boom")))
        assert type(back) is exc_type and "boom" in str(back)
    # unknown remote types arrive as RemoteError with the name attached
    back = tp.error_from_wire({"type": "SomethingExotic", "message": "m"})
    assert isinstance(back, tp.RemoteError) and back.rtype == "SomethingExotic"
    # QueueClosed maps to the retryable admission rejection
    class QueueClosed(RuntimeError):
        pass
    back = tp.error_from_wire(tp.error_to_wire(QueueClosed("shut")))
    assert isinstance(back, tp.Overloaded)


# ---------------------------------------------------------------------------
# framing state machine
# ---------------------------------------------------------------------------

def test_frame_roundtrip_over_socket():
    msgs = [{"id": i, "ok": True, "value": [i, "x" * i]} for i in range(5)]
    sock = _feed(b"".join(tp.pack_frame(m) for m in msgs))
    got = [tp.read_frame(sock) for _ in msgs]
    assert all(_eq(a, b) for a, b in zip(got, msgs))
    with pytest.raises(tp.ConnectionLost):
        tp.read_frame(sock)
    sock.close()


def test_bad_magic_is_frame_error():
    sock = _feed(b"XX" + tp.pack_frame({"x": 1})[2:])
    with pytest.raises(tp.FrameError, match="magic"):
        tp.read_frame(sock)
    sock.close()


def test_bad_codec_byte_is_frame_error():
    frame = bytearray(tp.pack_frame({"x": 1}))
    frame[2:3] = b"Z"
    sock = _feed(bytes(frame))
    with pytest.raises(tp.FrameError, match="codec"):
        tp.read_frame(sock)
    sock.close()


def test_oversized_length_is_frame_error():
    header = tp.MAGIC + b"J" + struct.pack(">I", tp.MAX_FRAME + 1)
    sock = _feed(header)
    with pytest.raises(tp.FrameError, match="exceeds"):
        tp.read_frame(sock)
    sock.close()


def test_pack_frame_enforces_max_size(monkeypatch):
    monkeypatch.setattr(tp, "MAX_FRAME", 64)
    with pytest.raises(tp.FrameError, match="too large"):
        tp.pack_frame({"blob": b"\x00" * 256})


def test_max_size_frame_roundtrips(monkeypatch):
    # a payload landing exactly on the cap is legal on both ends
    monkeypatch.setattr(tp, "MAX_FRAME", 4096)
    blob = b"\xa5" * 4000
    _, payload = tp.encode({"b": blob}, "msgpack"
                           if "msgpack" in _codecs() else "json")
    assert len(payload) <= 4096
    sock = _feed(tp.pack_frame({"b": blob}))
    assert tp.read_frame(sock)["b"] == blob
    sock.close()


def test_every_cut_inside_a_frame_is_frame_error():
    frame = tp.pack_frame({"id": 1, "value": list(range(20))})
    for cut in range(1, len(frame)):
        sock = _feed(frame[:cut])
        with pytest.raises(tp.FrameError):
            tp.read_frame(sock)
        sock.close()


def test_cut_at_frame_boundary_is_clean_close():
    frame = tp.pack_frame({"id": 1})
    sock = _feed(frame)
    tp.read_frame(sock)
    with pytest.raises(tp.ConnectionLost):
        tp.read_frame(sock)
    sock.close()
