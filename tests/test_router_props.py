"""Property tests for the pool router (``repro.serve.router``).

The routing contract the daemon builds on, stated as properties over
arbitrary streams/versions/pools rather than hand-picked cases:

* affinity is a **pure function** of ``(stream, version, pool)`` —
  order- and call-independent, always a pool member;
* HRW **minimal disruption** — removing one worker only remaps the
  streams that were affine to IT; every other stream keeps its worker
  (and adding the worker back restores the original placement);
* **spill never selects a dead worker** — ``route`` only ever returns
  a member of the alive set it was given, saturated or not, and below
  the spill threshold it IS the affine worker.

Hypothesis is a CI dependency (requirements-dev.txt), not a runtime
one, so the whole module skips where it is absent; the deterministic
router unit tests in ``tests/test_served_daemon.py`` keep baseline
coverage everywhere.
"""

from __future__ import annotations

import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.serve.router import (affine_worker, hrw_weight, route,  # noqa: E402
                                spill_worker)

streams = st.text(max_size=24)
versions = st.integers(min_value=0, max_value=1000)
pools = st.lists(st.integers(min_value=0, max_value=255),
                 min_size=1, max_size=12, unique=True)


@given(streams, versions, pools)
@settings(max_examples=200)
def test_affinity_is_a_pure_function_of_stream_version_pool(s, v, pool):
    wid = affine_worker(s, v, pool)
    assert wid in pool
    # call- and order-independent: same inputs, same placement
    assert affine_worker(s, v, pool) == wid
    assert affine_worker(s, v, list(reversed(pool))) == wid
    assert affine_worker(s, v, sorted(pool)) == wid


@given(streams, versions, pools)
@settings(max_examples=200)
def test_affinity_is_the_hrw_argmax(s, v, pool):
    wid = affine_worker(s, v, pool)
    best = max(hrw_weight(s, v, w) for w in pool)
    assert hrw_weight(s, v, wid) == best


@given(st.lists(streams, min_size=1, max_size=8, unique=True),
       versions, st.lists(st.integers(0, 255), min_size=2, max_size=12,
                          unique=True))
@settings(max_examples=150)
def test_removing_one_worker_only_remaps_its_own_streams(names, v, pool):
    placed = {s: affine_worker(s, v, pool) for s in names}
    for removed in pool:
        rest = [w for w in pool if w != removed]
        for s, wid in placed.items():
            moved = affine_worker(s, v, rest)
            if wid != removed:
                # minimal disruption: survivors keep their streams
                assert moved == wid
            else:
                assert moved in rest
    # and re-adding the worker restores the original placement exactly
    for s, wid in placed.items():
        assert affine_worker(s, v, pool) == wid


@given(streams, versions, versions)
@settings(max_examples=100)
def test_version_bump_is_the_only_single_stream_reshuffle_knob(s, v1, v2):
    pool = list(range(4))
    a1, a2 = affine_worker(s, v1, pool), affine_worker(s, v2, pool)
    if v1 == v2:
        assert a1 == a2
    else:
        assert a2 in pool               # may move — that is the point


@given(pools,
       st.dictionaries(st.integers(0, 255), st.integers(0, 100),
                       max_size=12))
@settings(max_examples=200)
def test_spill_picks_least_loaded_alive_never_dead(alive, depths):
    wid = spill_worker(alive, depths)
    assert wid in alive                 # dead workers are simply absent
    floor = min(depths.get(w, 0) for w in alive)
    assert depths.get(wid, 0) == floor
    # deterministic tie-break: lowest id among the least loaded
    assert wid == min(w for w in alive if depths.get(w, 0) == floor)


@given(streams, versions, pools,
       st.dictionaries(st.integers(0, 255), st.integers(0, 100),
                       max_size=12),
       st.integers(min_value=1, max_value=64))
@settings(max_examples=200)
def test_route_stays_inside_the_alive_set(s, v, alive, depths, spill_depth):
    wid = route(s, v, alive, depths, spill_depth)
    assert wid in alive
    affine = affine_worker(s, v, alive)
    if depths.get(affine, 0) < spill_depth:
        assert wid == affine            # below threshold: warmth wins
    else:
        assert wid == spill_worker(alive, depths)
