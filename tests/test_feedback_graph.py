"""Algorithm 1 (feedback-graph generation): properties + oracle match."""

import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import feedback_graph, feedback_graph_np

settings.register_profile("ci", max_examples=12, deadline=None,
                          database=None, derandomize=True)
settings.load_profile("ci")


def _case(seed, K, budget_mult):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.05, 1.0, K)
    c = rng.uniform(0.05, 1.0, K)
    B = budget_mult * c.max()      # (a3): B >= max cost
    return w, c, B


@given(st.integers(0, 10_000), st.sampled_from([3, 8, 22]),
       st.floats(1.0, 6.0))
def test_budget_never_violated(seed, K, budget_mult):
    """The hard guarantee of the paper: every out-neighborhood costs <= B,
    so ANY drawn node yields a transmit set within budget."""
    w, c, B = _case(seed, K, budget_mult)
    adj = np.asarray(feedback_graph(jnp.log(w), jnp.asarray(c),
                                    jnp.float32(B), jnp.full((K,), 1e30)))
    for k in range(K):
        assert c[adj[k]].sum() <= B + 1e-5


@given(st.integers(0, 10_000), st.sampled_from([3, 8, 22]), st.floats(1.0, 4.0))
def test_self_loops_always_present(seed, K, budget_mult):
    w, c, B = _case(seed, K, budget_mult)
    adj = np.asarray(feedback_graph(jnp.log(w), jnp.asarray(c),
                                    jnp.float32(B), jnp.full((K,), 1e30)))
    assert np.diag(adj).all()


@given(st.integers(0, 5_000), st.sampled_from([4, 9]), st.floats(1.2, 4.0))
def test_matches_numpy_oracle(seed, K, budget_mult):
    """lax.while_loop implementation == literal pseudo-code transcription."""
    w, c, B = _case(seed, K, budget_mult)
    adj_j = np.asarray(feedback_graph(jnp.log(w), jnp.asarray(c),
                                      jnp.float32(B), jnp.full((K,), 1e30)))
    adj_n = feedback_graph_np(w, c, B, np.full(K, 1e30))
    assert (adj_j == adj_n).all()


@given(st.integers(0, 5_000), st.sampled_from([5, 10]))
def test_weight_constraint_monotone(seed, K):
    """With a finite previous-round weight sum, the new neighborhood's
    weight sum never exceeds it (eq. 2's second constraint)."""
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.05, 1.0, K)
    c = rng.uniform(0.05, 0.5, K)
    B = 3.0
    w_prev = rng.uniform(w.max(), w.sum(), K)   # feasible but binding
    adj = np.asarray(feedback_graph(jnp.log(w), jnp.asarray(c),
                                    jnp.float32(B),
                                    jnp.asarray(np.log(w_prev),
                                                jnp.float32)))
    for k in range(K):
        # self loop always allowed; appended nodes respect the cap
        extra = adj[k] & (np.arange(K) != k)
        if extra.any():
            assert w[adj[k]].sum() <= w_prev[k] * (1 + 1e-4)


@given(st.integers(0, 5_000))
def test_ineligible_leader_extreme_spread_matches_oracle(seed):
    """Regression (per-row score shift): a high-weight node that is
    *ineligible* (cost alone exceeds the budget) must not crush the
    eq.-(3) scores of the candidates that actually compete.  With the old
    global-max shift, eligible weights ~120 nats below the leader all
    underflowed to ratio 0 and the argmax degenerated to lowest-index;
    the per-row eligible shift keeps them exact.  Non-hypothesis batched
    coverage: tests/test_feedback_graph_batched.py."""
    K = 10
    r = np.random.default_rng(seed)
    lw = np.zeros(K)
    lw[1:] = -120.0 + r.uniform(0.0, 5.0, K - 1)
    c = np.empty(K)
    c[0] = 10.0                       # leader can never be appended
    c[1:] = r.uniform(0.1, 1.0, K - 1)
    adj = np.asarray(feedback_graph(jnp.asarray(lw, jnp.float32),
                                    jnp.asarray(c, jnp.float32),
                                    jnp.float32(3.0), jnp.full((K,), 1e30)))
    adj_np = feedback_graph_np(np.exp(lw), c, 3.0, np.full(K, 1e30))
    assert (adj == adj_np).all()


def test_greedy_prefers_cheap_high_weight():
    """eq. (3): among eligible nodes the max w/(cost_sum + c) is appended
    first — a cheap good model beats an expensive equal one."""
    w = np.array([1.0, 0.9, 0.9])
    c = np.array([1.0, 1.0, 0.1])
    adj = feedback_graph_np(w, c, 1.2, np.full(3, 1e30))
    # node 0: budget 1.2, self costs 1.0 -> only node 2 (c=0.1) fits
    assert adj[0, 2] and not adj[0, 1]


def test_larger_budget_denser_graph():
    rng = np.random.default_rng(1)
    K = 12
    w = rng.uniform(0.1, 1.0, K)
    c = rng.uniform(0.1, 1.0, K)
    prev = np.full(K, 1e30)
    edges = []
    for B in (1.0, 2.0, 4.0, 8.0):
        adj = feedback_graph_np(w, c, B * c.max(), prev)
        edges.append(adj.sum())
    assert edges == sorted(edges), f"density should grow with budget {edges}"
